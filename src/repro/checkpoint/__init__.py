"""Erasure-coded checkpointing: the paper's repair algorithms deployed as
the fault-tolerance layer of the training framework."""

from repro.checkpoint.ec_checkpoint import (  # noqa: F401
    ECCheckpointConfig,
    ECCheckpointer,
    RepairReport,
)
