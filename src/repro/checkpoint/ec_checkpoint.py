"""Erasure-coded sharded checkpoints with BMFRepair/MSRepair recovery.

Layout on disk:
  <dir>/step_<N>/manifest.json          treedef, shapes, dtypes, code, placement
  <dir>/step_<N>/domain_<d>.bin         every block placed on failure domain d

The flattened train-state blob is split into stripes of k chunk-sized data
blocks; n-k parity blocks per stripe come from the `gf256_matmul` Pallas
kernel (all stripes in one batched call). Blocks are placed RAID-5-rotated
across `num_domains` failure domains (hosts or pods).

Losing up to n-k domains is repaired *in place*: the repair planner
(msrepair+bmf by default — the paper's algorithms; any baseline scheme can
be selected for ablation) produces the transfer schedule, the simulator
prices it under the cluster's bandwidth process (this is the number an
operator cares about: repair-time-to-restore redundancy), and the data
plane reconstructs the bytes with the RS kernel, verified by checksum.

Saves are double-buffered on a background thread (async checkpointing off
the training critical path); commits are atomic via manifest rename.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario, SimResult
from repro.ec import stripe as stripe_lib
from repro.ec.rs import RSCode
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class ECCheckpointConfig:
    directory: str
    n: int = 6
    k: int = 4
    chunk_bytes: int = 1 << 20          # 1 MiB blocks
    num_domains: int = 8
    scheme: str = "msrepair"            # repair planner for multi-failure
    single_scheme: str = "bmf"          # repair planner for single failure
    async_save: bool = True


@dataclasses.dataclass
class RepairReport:
    lost_domains: tuple[int, ...]
    stripes_repaired: int
    blocks_repaired: int
    sim: SimResult | None
    wall_seconds: float


class ECCheckpointer:
    def __init__(self, cfg: ECCheckpointConfig,
                 bw: BandwidthProcess | None = None,
                 ingress: IngressModel | None = None):
        self.cfg = cfg
        self.code = RSCode(cfg.n, cfg.k)
        self.bw = bw
        self.ingress = ingress or IngressModel()
        self._thread: threading.Thread | None = None
        os.makedirs(cfg.directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def _flatten(self, state) -> tuple[np.ndarray, dict]:
        leaves, treedef = jax.tree.flatten(state)
        arrs = [np.asarray(l) for l in leaves]
        meta = {
            "shapes": [list(a.shape) for a in arrs],
            "dtypes": [str(a.dtype) for a in arrs],
            "treedef": str(treedef),
        }
        blob = (np.concatenate([a.reshape(-1).view(np.uint8) for a in arrs])
                if arrs else np.zeros(0, np.uint8))
        return blob, meta

    def _unflatten(self, blob: np.ndarray, meta: dict, template) -> object:
        leaves, treedef = jax.tree.flatten(template)
        out, off = [], 0
        for shape, dtype in zip(meta["shapes"], meta["dtypes"]):
            nb = int(np.prod(shape)) * np.dtype(dtype).itemsize
            arr = blob[off: off + nb].view(np.dtype(dtype)).reshape(shape)
            out.append(jnp.asarray(arr))
            off += nb
        return jax.tree.unflatten(treedef, out)

    def save(self, step: int, state, *, wait: bool = False) -> str:
        """Encode + write. Async by default (double-buffered)."""
        blob, meta = self._flatten(state)
        if self._thread is not None:
            self._thread.join()                 # previous save must land
        if self.cfg.async_save and not wait:
            self._thread = threading.Thread(
                target=self._write, args=(step, blob, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, blob, meta)
        return self._step_dir(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.cfg.directory, f"step_{step:08d}")

    def _write(self, step: int, blob: np.ndarray, meta: dict) -> None:
        cfg, code = self.cfg, self.code
        chunks = stripe_lib.split_blob(blob, code.k, cfg.chunk_bytes)
        num_stripes = chunks.shape[0]
        # batched parity for ALL stripes in one kernel call:
        # (S, k, C) -> (k, S*C)
        data_k = np.ascontiguousarray(chunks.transpose(1, 0, 2)).reshape(
            code.k, -1)
        parity = np.asarray(ops.rs_encode(code.parity_coeffs(),
                                          jnp.asarray(data_k)))
        parity = parity.reshape(code.m, num_stripes, cfg.chunk_bytes
                                ).transpose(1, 0, 2)   # (S, m, C)
        blocks = np.concatenate([chunks, parity], axis=1)   # (S, n, C)
        stripes = stripe_lib.place_stripes(num_stripes, code, cfg.num_domains)

        d = self._step_dir(step)
        os.makedirs(d + ".tmp", exist_ok=True)
        per_domain: dict[int, list[tuple[int, int]]] = {}
        for s in stripes:
            for b, node in enumerate(s.node_ids):
                per_domain.setdefault(node, []).append((s.stripe_id, b))
        checksums = {}
        for dom, entries in per_domain.items():
            buf = np.concatenate([blocks[sid, b] for sid, b in entries])
            path = os.path.join(d + ".tmp", f"domain_{dom}.bin")
            buf.tofile(path)
            checksums[str(dom)] = zlib.crc32(buf.tobytes())
        manifest = {
            "step": step,
            "total_bytes": int(blob.size),
            "n": code.n, "k": code.k,
            "chunk_bytes": cfg.chunk_bytes,
            "num_stripes": num_stripes,
            "num_domains": cfg.num_domains,
            "checksums": checksums,
            **meta,
        }
        with open(os.path.join(d + ".tmp", "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            import shutil
            shutil.rmtree(d)
        os.rename(d + ".tmp", d)                # atomic commit

    # ------------------------------------------------------------------ load
    def latest_step(self) -> int | None:
        steps = [int(x.split("_")[1]) for x in os.listdir(self.cfg.directory)
                 if x.startswith("step_") and not x.endswith(".tmp")]
        return max(steps) if steps else None

    def _read_domains(self, d: str, manifest: dict,
                      lost: set[int]) -> dict[int, np.ndarray]:
        out = {}
        for dom in range(manifest["num_domains"]):
            if dom in lost:
                continue
            path = os.path.join(d, f"domain_{dom}.bin")
            if not os.path.exists(path):
                continue
            buf = np.fromfile(path, dtype=np.uint8)
            if zlib.crc32(buf.tobytes()) != manifest["checksums"].get(str(dom)):
                continue                        # corrupt domain == lost
            out[dom] = buf
        return out

    def load(self, template, *, step: int | None = None,
             lost_domains: tuple[int, ...] = ()) -> tuple[object, RepairReport]:
        """Restore train state; repair any blocks on lost domains."""
        cfg, code = self.cfg, self.code
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        lost = set(lost_domains)
        domains = self._read_domains(d, manifest, lost)
        missing = set(range(manifest["num_domains"])) - set(domains)

        stripes = stripe_lib.place_stripes(
            manifest["num_stripes"], code, manifest["num_domains"])
        cb = manifest["chunk_bytes"]
        # domain files are ordered by (stripe, block) per _write
        per_domain_order: dict[int, list[tuple[int, int]]] = {}
        for s in stripes:
            for b, node in enumerate(s.node_ids):
                per_domain_order.setdefault(node, []).append((s.stripe_id, b))

        block_of: dict[tuple[int, int], np.ndarray] = {}
        for dom, buf in domains.items():
            for i, (sid, b) in enumerate(per_domain_order[dom]):
                block_of[(sid, b)] = buf[i * cb: (i + 1) * cb]

        t0 = time.time()
        stripes_repaired = blocks_repaired = 0
        sim_result = None
        for s in stripes:
            lost_blocks = [b for b in range(code.n)
                           if (s.stripe_id, b) not in block_of]
            lost_data = [b for b in lost_blocks if b < code.k]
            if not lost_data:
                continue
            if len(lost_blocks) > code.m:
                raise RuntimeError(
                    f"stripe {s.stripe_id}: {len(lost_blocks)} blocks lost, "
                    f"only {code.m} tolerable")
            helpers = [b for b in range(code.n) if b not in lost_blocks][: code.k]
            coeff = code.repair_coeffs(tuple(lost_data), tuple(helpers))
            hblocks = jnp.asarray(
                np.stack([block_of[(s.stripe_id, b)] for b in helpers]))
            rec = np.asarray(ops.rs_reconstruct(coeff, hblocks))
            for i, b in enumerate(lost_data):
                block_of[(s.stripe_id, b)] = rec[i]
                blocks_repaired += 1
            stripes_repaired += 1
            if sim_result is None and self.bw is not None:
                sim_result = self._price_repair(lost_blocks)

        blob = np.concatenate(
            [block_of[(s.stripe_id, b)] for s in stripes for b in range(code.k)]
        )[: manifest["total_bytes"]]
        state = self._unflatten(blob, manifest, template)
        report = RepairReport(
            lost_domains=tuple(sorted(missing)),
            stripes_repaired=stripes_repaired,
            blocks_repaired=blocks_repaired,
            sim=sim_result,
            wall_seconds=time.time() - t0,
        )
        return state, report

    def _price_repair(self, lost_blocks: list[int]) -> SimResult:
        """Price one stripe's repair under the cluster bandwidth process
        using the configured scheme (the paper's algorithms)."""
        cfg = self.cfg
        sc = Scenario(
            num_nodes=max(cfg.num_domains, self.code.n),
            code=self.code,
            failed=tuple(lost_blocks),
            bw=self.bw,
            ingress=self.ingress,
            chunk_mb=cfg.chunk_bytes / 2**20,
        )
        scheme = (cfg.single_scheme if len(lost_blocks) == 1 else cfg.scheme)
        return RepairSimulator(sc).run(scheme)
