"""Pallas TPU kernel: GF(256) matrix multiply over bit-sliced chunks.

Computes out[o, :] = XOR_i ( C[o, i] (*) data[i, :] ) where (*) is GF(256)
multiplication, in the bit-plane domain (see repro/ec/bitplane.py):

  out_plane[o, bi, w] = XOR_{i, bj} plane[i, bj, w] & mask[o, i, bi, bj]

masks are pre-expanded uint32 {0, 0xFFFFFFFF} AND-masks of the 8x8 GF(2)
bit-matrix of each coefficient, so the inner loop is branch-free AND/XOR on
(8, BLOCK_W) uint32 tiles — pure VPU work, no gathers (TPU has no byte
shuffle; this is the TPU-native adaptation of ISA-L's PSHUFB method).

VMEM budget per grid step (BLOCK_W=512, k=16):
  planes (k, 8, 512) u32 = 256 KiB, masks (1, k, 8, 8) = 4 KiB,
  out (1, 8, 512) = 16 KiB  -> well under 16 MiB VMEM.
Lane dim 512 = 4x128 lanes; sublane dim 8 matches the u32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 512


def _kernel(mask_ref, plane_ref, out_ref, *, k: int):
    acc = jnp.zeros(out_ref.shape[1:], dtype=jnp.uint32)  # (8, BW)
    for i in range(k):          # static unroll: k is small (<= 16)
        for bj in range(8):
            d = plane_ref[i, bj, :]          # (BW,) u32
            msk = mask_ref[0, i, :, bj]      # (8,)  u32
            acc = acc ^ (d[None, :] & msk[:, None])
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def gf256_matmul_planes(
    masks: jax.Array,
    planes: jax.Array,
    *,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = True,
) -> jax.Array:
    """(m,k,8,8) u32 masks x (k,8,W) u32 planes -> (m,8,W) u32 planes.

    W is padded to a multiple of block_w internally.
    """
    m, k = masks.shape[0], masks.shape[1]
    kk, eight, w = planes.shape
    assert kk == k and eight == 8, (masks.shape, planes.shape)
    w_pad = -w % block_w
    if w_pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, w_pad)))
    wp = planes.shape[-1]
    grid = (m, wp // block_w)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, 8, 8), lambda o, t: (o, 0, 0, 0)),
            pl.BlockSpec((k, 8, block_w), lambda o, t: (0, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, 8, block_w), lambda o, t: (o, 0, t)),
        out_shape=jax.ShapeDtypeStruct((m, 8, wp), jnp.uint32),
        interpret=interpret,
    )(masks, planes)
    return out[:, :, :w]


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def gf256_scale_planes(
    masks: jax.Array,
    planes: jax.Array,
    *,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = True,
) -> jax.Array:
    """(M,1,8,8) u32 masks x (M,8,W) u32 planes -> (M,8,W) u32 planes.

    The batched data-plane premultiply: row r is scaled by its *own*
    coefficient mask (elementwise over rows, not an (m, k) contraction).
    Same kernel body as `gf256_matmul_planes` (`_kernel` with k=1), driven
    over an (M, W/block_w) grid — one `pallas_call` covers every
    (job, helper) chunk of a whole plan batch instead of one call per
    chunk.
    """
    m = masks.shape[0]
    assert masks.shape[1:] == (1, 8, 8), masks.shape
    mm, eight, w = planes.shape
    assert mm == m and eight == 8, (masks.shape, planes.shape)
    w_pad = -w % block_w
    if w_pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, w_pad)))
    wp = planes.shape[-1]
    grid = (m, wp // block_w)
    out = pl.pallas_call(
        functools.partial(_kernel, k=1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 8, 8), lambda r, t: (r, 0, 0, 0)),
            pl.BlockSpec((1, 8, block_w), lambda r, t: (r, 0, t)),
        ],
        out_specs=pl.BlockSpec((1, 8, block_w), lambda r, t: (r, 0, t)),
        out_shape=jax.ShapeDtypeStruct((m, 8, wp), jnp.uint32),
        interpret=interpret,
    )(masks, planes)
    return out[:, :, :w]
