"""Pure-jnp oracles for the Pallas kernels.

Two *independent* formulations:
  * byte domain — log/exp (dense MUL_TABLE) Galois multiply + XOR accumulate,
  * plane domain — the same bit-matrix math as the kernel but in plain jnp.
Tests cross-check kernel vs both, and both vs the numpy peasant-multiply
ground truth in repro/ec/gf256.py.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.ec import bitplane, gf256


def gf256_matmul_bytes_ref(coeff: np.ndarray, data: jnp.ndarray) -> jnp.ndarray:
    """(m,k) static uint8 coeffs x (k, nbytes) uint8 -> (m, nbytes) uint8.

    Byte-domain oracle: per-coefficient 256-entry table lookup (jnp.take)
    XOR-accumulated. Coefficients must be concrete (numpy) — they select
    which table row to use.
    """
    coeff = np.asarray(coeff, dtype=np.uint8)
    m, k = coeff.shape
    assert data.shape[0] == k
    outs = []
    for o in range(m):
        acc = jnp.zeros(data.shape[1:], dtype=jnp.uint8)
        for i in range(k):
            c = int(coeff[o, i])
            if c == 0:
                continue
            if c == 1:
                acc = acc ^ data[i]
            else:
                row = jnp.asarray(gf256.MUL_TABLE[c])  # (256,)
                acc = acc ^ jnp.take(row, data[i].astype(jnp.int32))
        outs.append(acc)
    return jnp.stack(outs)


def gf256_matmul_planes_ref(masks: jnp.ndarray, planes: jnp.ndarray) -> jnp.ndarray:
    """Plane-domain oracle, vectorized einsum-of-XOR formulation."""
    # out[o, bi, w] = XOR_{i, bj} planes[i, bj, w] & masks[o, i, bi, bj]
    m = masks.shape[0]
    k = planes.shape[0]
    outs = []
    for o in range(m):
        acc = jnp.zeros((8, planes.shape[-1]), dtype=jnp.uint32)
        for i in range(k):
            for bj in range(8):
                acc = acc ^ (planes[i, bj][None, :] & masks[o, i, :, bj][:, None])
        outs.append(acc)
    return jnp.stack(outs)


def xor_reduce_ref(words: jnp.ndarray) -> jnp.ndarray:
    """(k, W) uint32 -> (W,) uint32."""
    out = words[0]
    for i in range(1, words.shape[0]):
        out = out ^ words[i]
    return out


def gf256_scale_batch_np(coeffs: np.ndarray, data: np.ndarray) -> np.ndarray:
    """(M,) uint8 coeffs x (M, nbytes) uint8 -> (M, nbytes): per-row scale.

    Numpy oracle for the batched premultiply (`kernels.ops.gf256_scale_batch`):
    one dense MUL_TABLE gather covers the whole batch. This is the
    non-interpret ref path the batched data plane runs off-TPU.
    """
    coeffs = np.asarray(coeffs, dtype=np.uint8).reshape(-1)
    data = np.asarray(data, dtype=np.uint8)
    assert data.shape[0] == coeffs.shape[0], (coeffs.shape, data.shape)
    return gf256.MUL_TABLE[coeffs[:, None], data]


def xor_reduce_segments_np(chunks: np.ndarray, groups: np.ndarray) -> np.ndarray:
    """(T, nbytes) chunks + (G, Kmax) row-index groups (-1 padded) ->
    (G, nbytes): XOR of each group's member rows (numpy oracle)."""
    chunks = np.asarray(chunks, dtype=np.uint8)
    groups = np.asarray(groups, dtype=np.int64)
    if groups.size == 0:
        return np.zeros((groups.shape[0], chunks.shape[-1]), dtype=np.uint8)
    rows = chunks[np.maximum(groups, 0)]          # (G, K, nbytes) copy
    rows[groups < 0] = 0
    return np.bitwise_xor.reduce(rows, axis=1)


def gf256_matmul_np(coeff: np.ndarray, data: np.ndarray) -> np.ndarray:
    """Numpy ground truth (table-based; see gf256.gf_matmul_np)."""
    return gf256.gf_matmul_np(coeff, data)


def bitplane_roundtrip_np(data: np.ndarray) -> np.ndarray:
    return bitplane.unpack_np(bitplane.pack_np(data), data.shape[-1])
