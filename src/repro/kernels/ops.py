"""Public jit'd entry points for the EC data plane.

Dispatches to the Pallas kernels (compiled on TPU, interpret=True elsewhere —
this container is CPU-only so interpret mode exercises the kernel bodies).
Byte-level convenience wrappers handle bit-slicing at the boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.ec import bitplane
from repro.kernels import ref
from repro.kernels.gf256_matmul import gf256_matmul_planes, gf256_scale_planes
from repro.kernels.xor_reduce import xor_reduce_groups_words, xor_reduce_words


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _use_kernel_default() -> bool:
    """Batched entry points compile the kernels on TPU and fall back to
    the numpy oracles in `repro.kernels.ref` everywhere else — unlike the
    per-chunk wrappers above, whose interpret mode exists to *exercise*
    the kernel bodies, the batched paths are sized for throughput and the
    Pallas interpreter is not a performance proxy."""
    return not _interpret_default()


def gf256_matmul(
    coeff: np.ndarray,
    data: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(m, k) uint8 GF coefficients x (k, nbytes) uint8 -> (m, nbytes) uint8.

    The workhorse of RS encode / decode / repair-term premultiplication.
    `coeff` must be concrete (it parametrizes the bit-matrix masks).
    """
    coeff = np.asarray(coeff, dtype=np.uint8)
    if not use_kernel:
        return ref.gf256_matmul_bytes_ref(coeff, data)
    interpret = _interpret_default() if interpret is None else interpret
    nbytes = data.shape[-1]
    masks = jnp.asarray(bitplane.coeff_to_masks_np(coeff))
    planes = bitplane.pack_jnp(data)
    out_planes = gf256_matmul_planes(masks, planes, interpret=interpret)
    return bitplane.unpack_jnp(out_planes, nbytes)


def xor_reduce(
    chunks: jax.Array, *, use_kernel: bool = True, interpret: bool | None = None
) -> jax.Array:
    """(k, nbytes) uint8 -> (nbytes,) uint8 XOR of all chunks."""
    if chunks.shape[0] == 1:
        return chunks[0]
    if not use_kernel:
        out = chunks[0]
        for i in range(1, chunks.shape[0]):
            out = out ^ chunks[i]
        return out
    interpret = _interpret_default() if interpret is None else interpret
    nbytes = chunks.shape[-1]
    pad = -nbytes % 4
    if pad:
        chunks = jnp.pad(chunks, ((0, 0), (0, pad)))
    words = jax.lax.bitcast_convert_type(
        chunks.reshape(chunks.shape[0], -1, 4), jnp.uint32
    ).reshape(chunks.shape[0], -1)
    out = xor_reduce_words(words, interpret=interpret)
    out_bytes = jax.lax.bitcast_convert_type(out[:, None], jnp.uint8).reshape(-1)
    return out_bytes[:nbytes]


def gf256_scale_batch(
    coeffs: np.ndarray,
    data,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """(M,) uint8 coeffs x (M, nbytes) uint8 -> (M, nbytes): row i scaled
    by its own coefficient.

    The batched data-plane premultiply: one call covers every
    (job, helper) chunk of a plan batch. `use_kernel=None` (the default)
    compiles the Pallas kernel on TPU and takes the numpy oracle
    (`ref.gf256_scale_batch_np`) elsewhere; the kernel path drives
    `gf256_scale_planes` over an (M, W/block) grid — the same kernel body
    as `gf256_matmul`, one grid row per chunk instead of one
    `pallas_call` per chunk. Returns numpy on the ref path, a device
    array on the kernel path.
    """
    coeffs = np.asarray(coeffs, dtype=np.uint8).reshape(-1)
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    if coeffs.size == 0 or not use_kernel:
        return ref.gf256_scale_batch_np(coeffs, np.asarray(data))
    interpret = _interpret_default() if interpret is None else interpret
    nbytes = data.shape[-1]
    masks = jnp.asarray(bitplane.coeff_to_masks_np(coeffs[:, None]))
    planes = bitplane.pack_jnp(jnp.asarray(data))
    out_planes = gf256_scale_planes(masks, planes, interpret=interpret)
    return bitplane.unpack_jnp(out_planes, nbytes)


def xor_reduce_segments(
    chunks,
    groups: np.ndarray,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """(T, nbytes) uint8 chunks + (G, Kmax) int row-index groups (-1
    padded) -> (G, nbytes): XOR-fold of each group's member rows.

    The batched data-plane merge: group g holds the payload rows arriving
    at one (case, destination) in a round. `use_kernel=None` compiles on
    TPU and takes `ref.xor_reduce_segments_np` elsewhere; the kernel path
    gathers groups to a dense (G, Kmax, W) word tensor (index -1 reads an
    all-zero row — XOR identity) and drives the `xor_reduce` kernel body
    over a (G, W/block) grid. Returns numpy on the ref path, a device
    array on the kernel path.
    """
    groups = np.asarray(groups, dtype=np.int64)
    if use_kernel is None:
        use_kernel = _use_kernel_default()
    if groups.shape[0] == 0 or not use_kernel:
        return ref.xor_reduce_segments_np(np.asarray(chunks), groups)
    interpret = _interpret_default() if interpret is None else interpret
    chunks = jnp.asarray(chunks)
    t, nbytes = chunks.shape
    pad = -nbytes % 4
    if pad:
        chunks = jnp.pad(chunks, ((0, 0), (0, pad)))
    words = jax.lax.bitcast_convert_type(
        chunks.reshape(t, -1, 4), jnp.uint32
    ).reshape(t, -1)
    words = jnp.concatenate(
        [words, jnp.zeros((1, words.shape[1]), dtype=jnp.uint32)])
    gathered = words[jnp.where(groups >= 0, groups, t)]   # (G, Kmax, W)
    out = xor_reduce_groups_words(gathered, interpret=interpret)
    out_bytes = jax.lax.bitcast_convert_type(
        out, jnp.uint8).reshape(groups.shape[0], -1)
    return out_bytes[:, :nbytes]


def rs_encode(parity_coeff: np.ndarray, data_blocks: jax.Array) -> jax.Array:
    """(n-k, k) coeffs x (k, nbytes) data -> (n-k, nbytes) parity."""
    return gf256_matmul(parity_coeff, data_blocks)


def rs_reconstruct(repair_coeff: np.ndarray, helper_blocks: jax.Array) -> jax.Array:
    """(f, k) repair coeffs x (k, nbytes) helpers -> (f, nbytes) lost blocks."""
    return gf256_matmul(repair_coeff, helper_blocks)
