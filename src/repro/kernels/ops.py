"""Public jit'd entry points for the EC data plane.

Dispatches to the Pallas kernels (compiled on TPU, interpret=True elsewhere —
this container is CPU-only so interpret mode exercises the kernel bodies).
Byte-level convenience wrappers handle bit-slicing at the boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.ec import bitplane
from repro.kernels import ref
from repro.kernels.gf256_matmul import gf256_matmul_planes
from repro.kernels.xor_reduce import xor_reduce_words


@functools.lru_cache(maxsize=1)
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def gf256_matmul(
    coeff: np.ndarray,
    data: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """(m, k) uint8 GF coefficients x (k, nbytes) uint8 -> (m, nbytes) uint8.

    The workhorse of RS encode / decode / repair-term premultiplication.
    `coeff` must be concrete (it parametrizes the bit-matrix masks).
    """
    coeff = np.asarray(coeff, dtype=np.uint8)
    if not use_kernel:
        return ref.gf256_matmul_bytes_ref(coeff, data)
    interpret = _interpret_default() if interpret is None else interpret
    nbytes = data.shape[-1]
    masks = jnp.asarray(bitplane.coeff_to_masks_np(coeff))
    planes = bitplane.pack_jnp(data)
    out_planes = gf256_matmul_planes(masks, planes, interpret=interpret)
    return bitplane.unpack_jnp(out_planes, nbytes)


def xor_reduce(
    chunks: jax.Array, *, use_kernel: bool = True, interpret: bool | None = None
) -> jax.Array:
    """(k, nbytes) uint8 -> (nbytes,) uint8 XOR of all chunks."""
    if chunks.shape[0] == 1:
        return chunks[0]
    if not use_kernel:
        out = chunks[0]
        for i in range(1, chunks.shape[0]):
            out = out ^ chunks[i]
        return out
    interpret = _interpret_default() if interpret is None else interpret
    nbytes = chunks.shape[-1]
    pad = -nbytes % 4
    if pad:
        chunks = jnp.pad(chunks, ((0, 0), (0, pad)))
    words = jax.lax.bitcast_convert_type(
        chunks.reshape(chunks.shape[0], -1, 4), jnp.uint32
    ).reshape(chunks.shape[0], -1)
    out = xor_reduce_words(words, interpret=interpret)
    out_bytes = jax.lax.bitcast_convert_type(out[:, None], jnp.uint8).reshape(-1)
    return out_bytes[:nbytes]


def rs_encode(parity_coeff: np.ndarray, data_blocks: jax.Array) -> jax.Array:
    """(n-k, k) coeffs x (k, nbytes) data -> (n-k, nbytes) parity."""
    return gf256_matmul(parity_coeff, data_blocks)


def rs_reconstruct(repair_coeff: np.ndarray, helper_blocks: jax.Array) -> jax.Array:
    """(f, k) repair coeffs x (k, nbytes) helpers -> (f, nbytes) lost blocks."""
    return gf256_matmul(repair_coeff, helper_blocks)
