"""Pallas TPU kernel: XOR-reduce k chunks into one.

The PPR / BMFRepair aggregation step: helper partial results (already Galois-
premultiplied, c_i (*) B_i) combine by plain XOR. Operates on raw uint32
words (no bit-slicing needed: XOR is byte-order agnostic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 1024


def _kernel(x_ref, out_ref, *, k: int):
    acc = x_ref[0, :]
    for i in range(1, k):
        acc = acc ^ x_ref[i, :]
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def xor_reduce_words(
    words: jax.Array, *, block_w: int = DEFAULT_BLOCK_W, interpret: bool = True
) -> jax.Array:
    """(k, W) uint32 -> (W,) uint32 running XOR."""
    k, w = words.shape
    w_pad = -w % block_w
    if w_pad:
        words = jnp.pad(words, ((0, 0), (0, w_pad)))
    wp = words.shape[-1]
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(wp // block_w,),
        in_specs=[pl.BlockSpec((k, block_w), lambda t: (0, t))],
        out_specs=pl.BlockSpec((1, block_w), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, wp), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[0, :w]


def _group_kernel(x_ref, out_ref, *, k: int):
    acc = x_ref[0, 0, :]
    for i in range(1, k):
        acc = acc ^ x_ref[0, i, :]
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def xor_reduce_groups_words(
    words: jax.Array, *, block_w: int = DEFAULT_BLOCK_W, interpret: bool = True
) -> jax.Array:
    """(G, K, W) uint32 -> (G, W) uint32: XOR over axis 1, per group.

    The segment-XOR of the batched data plane: group g holds the (padded)
    payloads arriving at one (case, destination) in a round. Same reduce
    body as `xor_reduce_words`, driven over a (G, W/block_w) grid — one
    `pallas_call` folds every fan-in group of a whole round batch.
    """
    g, k, w = words.shape
    w_pad = -w % block_w
    if w_pad:
        words = jnp.pad(words, ((0, 0), (0, 0), (0, w_pad)))
    wp = words.shape[-1]
    out = pl.pallas_call(
        functools.partial(_group_kernel, k=k),
        grid=(g, wp // block_w),
        in_specs=[pl.BlockSpec((1, k, block_w), lambda r, t: (r, 0, t))],
        out_specs=pl.BlockSpec((1, block_w), lambda r, t: (r, t)),
        out_shape=jax.ShapeDtypeStruct((g, wp), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[:, :w]
