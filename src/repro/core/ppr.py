"""PPR — Partial Parallel Repair (Mitra et al., EuroSys'16) round structure.

Single-node repair: helpers h_1..h_k each locally compute c_i (*) B_i; the
partial results combine down a binomial reduction tree rooted at the
requestor r. ceil(log2(k+1)) rounds; each node sends/receives at most once
per round (paper Fig. 4: RS(6,3) -> ts1: D2->D1, P1->D3; ts2: D3->D1).

`traditional` (baseline in Fig. 9): all k helpers stream to r concurrently
in one star round — fan-in contention makes it slow (paper Fig. 2).
"""
from __future__ import annotations

import math

from repro.core.plan import FragmentState, Job, RepairPlan, Round, Transfer


def ppr_rounds(job: Job) -> list[Round]:
    """Binomial-tree reduction over positions [r, h1, ..., hk]."""
    k = len(job.helpers)
    nodes = [job.requestor, *job.helpers]          # position -> node id
    state = FragmentState([job])
    rounds: list[Round] = []
    num_rounds = math.ceil(math.log2(k + 1)) if k > 0 else 0
    for t in range(1, num_rounds + 1):
        stride = 1 << (t - 1)
        rnd = Round()
        for i in range(stride, k + 1, 2 * stride):
            src_pos, dst_pos = i, i - stride
            src, dst = nodes[src_pos], nodes[dst_pos]
            frag = state.fragment_at(job.job_id, src)
            if frag is None:
                continue
            tr = Transfer(src=src, dst=dst, job=job.job_id, terms=frag)
            state.apply(tr)
            rnd.transfers.append(tr)
        if rnd.transfers:
            rounds.append(rnd)
    assert state.job_done(job.job_id), "PPR schedule incomplete"
    return rounds


def plan_ppr(job: Job) -> RepairPlan:
    return RepairPlan(jobs=[job], rounds=ppr_rounds(job), meta={"scheme": "ppr"})


def plan_traditional(job: Job) -> RepairPlan:
    """Star repair: every helper sends its term straight to the requestor."""
    rnd = Round(
        transfers=[
            Transfer(src=h, dst=job.requestor, job=job.job_id, terms=frozenset({h}))
            for h in job.helpers
        ]
    )
    return RepairPlan(jobs=[job], rounds=[rnd], meta={"scheme": "traditional"})
