"""MSRepair (paper Algorithm 2) + multi-node baselines (m-PPR, random).

Multi-node repair with node sets (paper eqs. 1-3):
  RP = failed/requestor nodes, R = intersection of all helper sets,
  NR = union of helper sets minus R.
Per round, transfers are chosen greedily scanning the priority classes
  {R,R} > {R,NR} > {NR,RP} > {NR,NR} > {R,RP} > {NR,R}
(sender-set, receiver-set), under one-role-per-node-per-round. A transfer
is *useful* iff the receiver already holds a fragment of the same job (XOR
merge) or is the job's requestor. Tie-break inside a class drains the most-
loaded sender first (nodes holding fragments of several jobs are future
bottlenecks), then lowest (job, src, dst) for determinism — this reproduces
the paper's Table II 3-round schedule for RS(7,4), see tests.

Helper selection follows the paper: maximize |NR| (spread helper sets as
disjointly as the survivor count allows).
"""
from __future__ import annotations

import numpy as np

from repro.core.plan import FragmentState, Job, RepairPlan, Round, Transfer
from repro.core.ppr import ppr_rounds


# ----------------------------------------------------------- helper selection
def select_helpers_multi(
    n: int, k: int, failed: list[int], *, extra_busy: set[int] | None = None
) -> list[tuple[int, ...]]:
    """Pick k helpers per failed node, maximizing |NR| (minimal overlap)."""
    survivors = [x for x in range(n) if x not in failed and x not in (extra_busy or set())]
    if len(survivors) < k:
        raise ValueError("not enough survivors to repair")
    jobs = len(failed)
    picks: list[list[int]] = [[] for _ in range(jobs)]
    # Round-robin over survivors: consecutive jobs take distinct nodes first,
    # so overlap only appears once survivors run out — this maximizes |NR|.
    idx = 0
    for _ in range(k):
        for j in range(jobs):
            # next survivor not already picked by this job
            for step in range(len(survivors)):
                cand = survivors[(idx + step) % len(survivors)]
                if cand not in picks[j]:
                    picks[j].append(cand)
                    idx = (idx + step + 1) % len(survivors)
                    break
            else:
                raise ValueError("helper selection failed")
    return [tuple(sorted(p)) for p in picks]


def node_sets(jobs: list[Job]) -> tuple[set[int], set[int], set[int]]:
    """(R, NR, RP) per paper eqs. (1)-(3)."""
    helper_sets = [set(j.helpers) for j in jobs]
    r: set[int] = set.intersection(*helper_sets) if helper_sets else set()
    nr: set[int] = set.union(*helper_sets) - r if helper_sets else set()
    rp = {j.requestor for j in jobs}
    return r, nr, rp


# ------------------------------------------------------------------ MSRepair
_PRIORITY = (("R", "R"), ("R", "NR"), ("NR", "RP"), ("NR", "NR"), ("R", "RP"), ("NR", "R"))


def msrepair_rounds(jobs: list[Job], *, max_rounds: int = 64) -> list[Round]:
    r_set, nr_set, rp_set = node_sets(jobs)

    def set_of(node: int) -> str:
        if node in rp_set:
            return "RP"
        if node in r_set:
            return "R"
        if node in nr_set:
            return "NR"
        return "IDLE"

    state = FragmentState(jobs)
    job_by_id = {j.job_id: j for j in jobs}
    rounds: list[Round] = []
    for _ in range(max_rounds):
        if state.all_done():
            break
        busy: set[int] = set()
        rnd = Round()

        def candidates_in(cls: tuple[str, str]) -> list[tuple]:
            cands = []
            for job_id, holders in state.holdings.items():
                if state.job_done(job_id):
                    continue
                req = job_by_id[job_id].requestor
                for src, terms in holders.items():
                    if src in busy or set_of(src) != cls[0] or src == req:
                        continue
                    for dst in list(holders.keys()) + [req]:
                        if dst == src or dst in busy or set_of(dst) != cls[1]:
                            continue
                        # useful: merge at dst, or delivery to requestor
                        if dst != req and dst not in holders:
                            continue
                        load = sum(
                            1 for h in state.holdings.values() if src in h
                        )
                        cands.append((-load, job_id, src, dst, frozenset(terms)))
            cands.sort()
            return cands

        for cls in _PRIORITY:
            while True:
                cands = candidates_in(cls)
                if not cands:
                    break
                _, job_id, src, dst, terms = cands[0]
                tr = Transfer(src=src, dst=dst, job=job_id, terms=terms)
                state.apply(tr)
                rnd.transfers.append(tr)
                busy.update((src, dst))
        if not rnd.transfers:
            raise RuntimeError("MSRepair stalled — no feasible transfer")
        rounds.append(rnd)
    else:
        raise RuntimeError("MSRepair exceeded max_rounds")
    return rounds


def plan_msrepair(jobs: list[Job]) -> RepairPlan:
    return RepairPlan(jobs=jobs, rounds=msrepair_rounds(jobs), meta={"scheme": "msrepair"})


# --------------------------------------------------------------------- m-PPR
def plan_mppr(jobs: list[Job]) -> RepairPlan:
    """m-PPR (Mitra et al.): reconstruction jobs effectively serialize —
    each failed block runs its PPR schedule back-to-back (paper Fig. 5 /
    Table II: 2x2=4 rounds for RS(6,3), 3+3=6 for RS(7,4))."""
    rounds: list[Round] = []
    for job in jobs:
        rounds.extend(ppr_rounds(job))
    return RepairPlan(jobs=jobs, rounds=rounds, meta={"scheme": "m-ppr"})


# -------------------------------------------------------------------- random
def plan_random(jobs: list[Job], *, seed: int = 0, max_rounds: int = 256) -> RepairPlan:
    """Random scheduling baseline: each round greedily packs uniformly-random
    useful transfers (ignoring the priority classes)."""
    rng = np.random.default_rng(seed)
    state = FragmentState(jobs)
    job_by_id = {j.job_id: j for j in jobs}
    rounds: list[Round] = []
    for _ in range(max_rounds):
        if state.all_done():
            break
        busy: set[int] = set()
        rnd = Round()
        while True:
            cands = []
            for job_id, holders in state.holdings.items():
                if state.job_done(job_id):
                    continue
                req = job_by_id[job_id].requestor
                for src, terms in holders.items():
                    if src in busy or src == req:
                        continue
                    for dst in list(holders.keys()) + [req]:
                        if dst == src or dst in busy:
                            continue
                        if dst != req and dst not in holders:
                            continue
                        cands.append((job_id, src, dst, frozenset(terms)))
            if not cands:
                break
            job_id, src, dst, terms = cands[int(rng.integers(len(cands)))]
            tr = Transfer(src=src, dst=dst, job=job_id, terms=terms)
            state.apply(tr)
            rnd.transfers.append(tr)
            busy.update((src, dst))
        if not rnd.transfers:
            raise RuntimeError("random scheduler stalled")
        rounds.append(rnd)
    else:
        raise RuntimeError("random scheduler exceeded max_rounds")
    return RepairPlan(jobs=jobs, rounds=rounds, meta={"scheme": "random"})
