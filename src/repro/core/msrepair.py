"""MSRepair (paper Algorithm 2) + multi-node baselines (m-PPR, random).

Multi-node repair with node sets (paper eqs. 1-3):
  RP = failed/requestor nodes, R = intersection of all helper sets,
  NR = union of helper sets minus R.
Per round, transfers are chosen greedily scanning the priority classes
  {R,R} > {R,NR} > {NR,RP} > {NR,NR} > {R,RP} > {NR,R}
(sender-set, receiver-set), under one-role-per-node-per-round. A transfer
is *useful* iff the receiver already holds a fragment of the same job (XOR
merge) or is the job's requestor. Tie-break inside a class drains the most-
loaded sender first (nodes holding fragments of several jobs are future
bottlenecks), then lowest (job, src, dst) for determinism — this reproduces
the paper's Table II 3-round schedule for RS(7,4), see tests.

Helper selection follows the paper: maximize |NR| (spread helper sets as
disjointly as the survivor count allows).

Since the array-native planner layer landed, this module is a thin object
facade: the schedulers themselves live in
`repro.core.engine.planner_arrays` (bitmask state, tuple transfers) and
are shared with the vectorized engine's `PlanArrays` path; the functions
here only wrap the tuple schedules back into `Round`/`Transfer` objects.
The facade output is pinned bit-identical to the historical object walk
by `tests/test_msrepair.py` and the oracle tests in
`tests/test_planner_arrays.py`.
"""
from __future__ import annotations

from repro.core.engine import planner_arrays as _pa
from repro.core.plan import Job, RepairPlan, Round, Transfer
from repro.core.ppr import ppr_rounds


# ----------------------------------------------------------- helper selection
def select_helpers_multi(
    n: int, k: int, failed: list[int], *, extra_busy: set[int] | None = None
) -> list[tuple[int, ...]]:
    """Pick k helpers per failed node, maximizing |NR| (minimal overlap)."""
    survivors = [x for x in range(n) if x not in failed and x not in (extra_busy or set())]
    if len(survivors) < k:
        raise ValueError("not enough survivors to repair")
    jobs = len(failed)
    picks: list[list[int]] = [[] for _ in range(jobs)]
    # Round-robin over survivors: consecutive jobs take distinct nodes first,
    # so overlap only appears once survivors run out — this maximizes |NR|.
    idx = 0
    for _ in range(k):
        for j in range(jobs):
            # next survivor not already picked by this job
            for step in range(len(survivors)):
                cand = survivors[(idx + step) % len(survivors)]
                if cand not in picks[j]:
                    picks[j].append(cand)
                    idx = (idx + step + 1) % len(survivors)
                    break
            else:
                raise ValueError("helper selection failed")
    return [tuple(sorted(p)) for p in picks]


def node_sets(jobs: list[Job]) -> tuple[set[int], set[int], set[int]]:
    """(R, NR, RP) per paper eqs. (1)-(3)."""
    helper_sets = [set(j.helpers) for j in jobs]
    r: set[int] = set.intersection(*helper_sets) if helper_sets else set()
    nr: set[int] = set.union(*helper_sets) - r if helper_sets else set()
    rp = {j.requestor for j in jobs}
    return r, nr, rp


# ------------------------------------------------------------------ MSRepair
_PRIORITY = _pa._PRIORITY


def _to_rounds(sched: _pa.Sched) -> list[Round]:
    """Wrap a tuple schedule back into the object plan IR."""
    from repro.core.engine.arrays import _mask_terms

    return [
        Round(transfers=[
            Transfer(src=src, dst=dst, job=job_id, terms=_mask_terms(mask))
            for src, dst, job_id, mask in rnd
        ])
        for rnd in sched
    ]


def msrepair_rounds(jobs: list[Job], *, max_rounds: int = 64) -> list[Round]:
    return _to_rounds(_pa.msrepair_schedule(jobs, max_rounds=max_rounds))


def plan_msrepair(jobs: list[Job]) -> RepairPlan:
    return RepairPlan(jobs=jobs, rounds=msrepair_rounds(jobs), meta={"scheme": "msrepair"})


# --------------------------------------------------------------------- m-PPR
def plan_mppr(jobs: list[Job]) -> RepairPlan:
    """m-PPR (Mitra et al.): reconstruction jobs effectively serialize —
    each failed block runs its PPR schedule back-to-back (paper Fig. 5 /
    Table II: 2x2=4 rounds for RS(6,3), 3+3=6 for RS(7,4))."""
    rounds: list[Round] = []
    for job in jobs:
        rounds.extend(ppr_rounds(job))
    return RepairPlan(jobs=jobs, rounds=rounds, meta={"scheme": "m-ppr"})


# -------------------------------------------------------------------- random
def plan_random(jobs: list[Job], *, seed: int = 0, max_rounds: int = 256) -> RepairPlan:
    """Random scheduling baseline: each round greedily packs uniformly-random
    useful transfers (ignoring the priority classes). Round draws come
    from a counter-based rng keyed on `(seed, round)` — see
    `repro.core.engine.planner_arrays.RANDOM_SCHEDULE_VERSION`."""
    rounds = _to_rounds(
        _pa.random_schedule(jobs, seed=seed, max_rounds=max_rounds))
    return RepairPlan(jobs=jobs, rounds=rounds, meta={"scheme": "random"})
