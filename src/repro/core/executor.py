"""Data-plane execution of repair plans, byte-verified.

The simulator times a plan; this module *runs* it. Two paths share the
semantics:

* `execute_plan` — the serial oracle below: every helper holds a real
  chunk, premultiplies its Galois coefficient with the Pallas
  `gf256_matmul` kernel, transfers move buffers between per-node stores,
  and merges XOR with the `xor_reduce` kernel. Relay nodes only buffer
  (the paper: forwarding nodes do not compute). At the end the
  requestor's buffer must equal the lost block bit-for-bit.
* `execute_plans_batch` (re-exported from
  `repro.core.engine.dataplane`) — the batched engine: a whole batch of
  compiled `PlanArrays` lowered to dense `(B, slots, nbytes)` buffer
  tensors, all rounds executed as gather → GF(256)-premultiply →
  segment-XOR array steps through the batched kernel entry points in
  `repro.kernels.ops`. Byte-identical to running the oracle case by
  case (`tests/test_dataplane.py` pins it); the oracle stays the
  reference this facade keeps readable.

**Invariant (both paths):** plans must be `validate_plan`-clean. The
executors implement store-and-forward faithfully — a source's buffer is
consumed when it sends, so a plan whose transfer sources a node that
already forwarded its fragment (or never held one) is *unexecutable*;
both paths raise `ValueError` on it rather than moving zeros.
`run_scheme` validates every plan it simulates, so every simulator-
produced plan satisfies this by construction.

`bytes_moved` counts the paper's real network cost: a relayed transfer
re-sends the whole chunk on every hop, so a path of length L moves
`(L - 1) * nbytes` bytes (store-and-forward, no computation at relays).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine.dataplane import (BatchExecutionResult,
                                         execute_plans_batch,
                                         identity_block_map)
from repro.core.plan import Job, RepairPlan
from repro.ec.rs import RSCode
from repro.kernels import ops

__all__ = [
    "ExecutionResult",
    "execute_plan",
    "BatchExecutionResult",
    "execute_plans_batch",
    "identity_block_map",
]


@dataclasses.dataclass
class ExecutionResult:
    reconstructed: dict[int, np.ndarray]   # job_id -> bytes
    verified: bool
    bytes_moved: int


def execute_plan(
    plan: RepairPlan,
    code: RSCode,
    codeword: np.ndarray,                  # (n, nbytes) original stripe
    *,
    use_kernel: bool = True,
    block_of: np.ndarray | None = None,
) -> ExecutionResult:
    """Serial oracle: walk one validated plan over real bytes.

    `block_of[node]` maps node ids to codeword block positions (identity
    when None — the simulator convention that node i holds block i); the
    sweep's byte-verification layer passes a real stripe placement
    (`repro.ec.stripe`) instead.
    """
    nbytes = codeword.shape[1]
    if block_of is None:
        nodes = [x for j in plan.jobs
                 for x in (j.failed_node, *j.helpers)] + [0]
        block_of = identity_block_map(max(nodes) + 1, code.n)
    block_of = np.asarray(block_of, dtype=np.int64)
    # per-(job, node) payload store
    store: dict[tuple[int, int], jnp.ndarray] = {}
    for job in plan.jobs:
        if block_of[job.failed_node] < 0 or any(
                block_of[h] < 0 for h in job.helpers):
            # -1 must not wrap into python negative indexing — that would
            # "repair" the wrong block and self-consistently verify it
            raise ValueError(
                f"job {job.job_id}: a failed/helper node holds no block "
                "under the given placement")
        coeffs = code.repair_coeffs(
            tuple([int(block_of[job.failed_node])]),
            tuple(int(block_of[h]) for h in job.helpers),
        )[0]  # (k,) coefficients, aligned with job.helpers
        for h, c in zip(job.helpers, coeffs):
            block = jnp.asarray(codeword[block_of[h]])
            pre = ops.gf256_matmul(
                np.array([[c]], dtype=np.uint8), block[None, :],
                use_kernel=use_kernel,
            )[0]
            store[(job.job_id, h)] = pre

    bytes_moved = 0
    for ri, rnd in enumerate(plan.rounds):
        arrivals: list[tuple[int, int, jnp.ndarray]] = []
        for t in rnd.transfers:
            # store-and-forward: sending consumes the buffer, so a source
            # drained in an earlier round cannot feed this one — only
            # validate_plan-clean plans are executable (module docstring)
            payload = store.pop((t.job, t.src), None)
            if payload is None:
                raise ValueError(
                    f"round {ri}: transfer {t} sources node {t.src} which "
                    f"holds no buffer for job {t.job} (consumed in an "
                    "earlier round?) — execute_plan requires a "
                    "validate_plan-clean plan")
            bytes_moved += nbytes * (len(t.path) - 1)   # relays re-send
            arrivals.append((t.job, t.dst, payload))
        for job_id, dst, payload in arrivals:
            existing = store.get((job_id, dst))
            if existing is None:
                store[(job_id, dst)] = payload
            else:
                store[(job_id, dst)] = ops.xor_reduce(
                    jnp.stack([existing, payload]), use_kernel=use_kernel
                )

    recon: dict[int, np.ndarray] = {}
    ok = True
    for job in plan.jobs:
        held = store.get((job.job_id, job.requestor))
        if held is None:
            recon[job.job_id] = np.zeros(nbytes, dtype=np.uint8)
            ok = False
            continue
        got = np.asarray(held)
        recon[job.job_id] = got
        if not np.array_equal(got, codeword[block_of[job.failed_node]]):
            ok = False
    return ExecutionResult(reconstructed=recon, verified=ok, bytes_moved=bytes_moved)
