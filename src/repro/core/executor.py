"""JAX data-plane execution of repair plans, byte-verified.

The simulator times a plan; this module *runs* it: every helper holds a
real chunk, premultiplies its Galois coefficient with the Pallas
`gf256_matmul` kernel, transfers move buffers between per-node stores, and
merges XOR with the `xor_reduce` kernel. Relay nodes only buffer (the
paper: forwarding nodes do not compute). At the end the requestor's buffer
must equal the lost block bit-for-bit.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.plan import Job, RepairPlan
from repro.ec.rs import RSCode
from repro.kernels import ops


@dataclasses.dataclass
class ExecutionResult:
    reconstructed: dict[int, np.ndarray]   # job_id -> bytes
    verified: bool
    bytes_moved: int


def execute_plan(
    plan: RepairPlan,
    code: RSCode,
    codeword: np.ndarray,                  # (n, nbytes) original stripe
    *,
    use_kernel: bool = True,
) -> ExecutionResult:
    nbytes = codeword.shape[1]
    # per-(job, node) payload store
    store: dict[tuple[int, int], jnp.ndarray] = {}
    for job in plan.jobs:
        coeffs = code.repair_coeffs(
            tuple([job.failed_node]), tuple(job.helpers)
        )[0]  # (k,) coefficients, aligned with job.helpers
        for h, c in zip(job.helpers, coeffs):
            block = jnp.asarray(codeword[h])
            pre = ops.gf256_matmul(
                np.array([[c]], dtype=np.uint8), block[None, :],
                use_kernel=use_kernel,
            )[0]
            store[(job.job_id, h)] = pre

    bytes_moved = 0
    for rnd in plan.rounds:
        arrivals: list[tuple[int, int, jnp.ndarray]] = []
        for t in rnd.transfers:
            payload = store.pop((t.job, t.src))
            bytes_moved += nbytes * (len(t.path) - 1)   # relays re-send
            arrivals.append((t.job, t.dst, payload))
        for job_id, dst, payload in arrivals:
            existing = store.get((job_id, dst))
            if existing is None:
                store[(job_id, dst)] = payload
            else:
                store[(job_id, dst)] = ops.xor_reduce(
                    jnp.stack([existing, payload]), use_kernel=use_kernel
                )

    recon: dict[int, np.ndarray] = {}
    ok = True
    for job in plan.jobs:
        got = np.asarray(store[(job.job_id, job.requestor)])
        recon[job.job_id] = got
        if not np.array_equal(got, codeword[job.failed_node]):
            ok = False
    return ExecutionResult(reconstructed=recon, verified=ok, bytes_moved=bytes_moved)
