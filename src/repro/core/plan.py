"""Repair-plan IR shared by all schedulers, the optimizer and the simulator.

A repair of failed blocks {f_j} proceeds in *rounds* ("timestamps" in the
paper). Each round holds parallel `Transfer`s; a transfer moves one
chunk-sized payload (RS linear aggregation keeps payloads block-sized) along
`path` — direct (len 2) or store-and-forward relayed through idle nodes
(len > 2, the BMF multi-level forwarding). `terms` records which helper
terms (c_i (*) B_i) are XOR-folded into the payload, enabling symbolic
verification and the real JAX data-plane execution.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict


@dataclasses.dataclass
class Transfer:
    src: int
    dst: int
    job: int                       # index into the failed-node list
    terms: frozenset[int]          # helper node ids folded into the payload
    path: tuple[int, ...] = ()     # full route; () or (src, dst) = direct

    def __post_init__(self):
        if not self.path:
            self.path = (self.src, self.dst)
        assert self.path[0] == self.src and self.path[-1] == self.dst
        assert len(set(self.path)) == len(self.path), "cyclic path"

    @property
    def relays(self) -> tuple[int, ...]:
        return self.path[1:-1]


@dataclasses.dataclass
class Round:
    transfers: list[Transfer] = dataclasses.field(default_factory=list)

    def nodes_in_use(self) -> set[int]:
        used: set[int] = set()
        for t in self.transfers:
            used.update(t.path)
        return used


@dataclasses.dataclass
class Job:
    """One failed block: its requestor (replacement node) and helper set."""

    job_id: int
    failed_node: int
    requestor: int
    helpers: tuple[int, ...]

    @property
    def full_terms(self) -> frozenset[int]:
        return frozenset(self.helpers)


@dataclasses.dataclass
class RepairPlan:
    jobs: list[Job]
    rounds: list[Round] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    def all_transfers(self) -> list[Transfer]:
        return [t for r in self.rounds for t in r.transfers]


# --------------------------------------------------------------- verification
class FragmentState:
    """Tracks which (job, node) holds which XOR-folded term sets."""

    def __init__(self, jobs: list[Job]):
        self.jobs = {j.job_id: j for j in jobs}
        # holdings[job][node] = set of terms folded together at that node
        self.holdings: dict[int, dict[int, set[int]]] = defaultdict(dict)
        for j in jobs:
            for h in j.helpers:
                self.holdings[j.job_id][h] = {h}

    def fragment_at(self, job: int, node: int) -> frozenset[int] | None:
        terms = self.holdings[job].get(node)
        return frozenset(terms) if terms else None

    def apply(self, t: Transfer) -> None:
        held = self.holdings[t.job].get(t.src)
        # Fragments are XOR-folded in place: a node holds at most one
        # fragment per job and must forward it whole (you cannot un-XOR).
        if held is None or set(t.terms) != held:
            raise ValueError(
                f"transfer {t} sends terms not matching src holding "
                f"(held={held}, sent={set(t.terms)})"
            )
        del self.holdings[t.job][t.src]
        dst_terms = self.holdings[t.job].setdefault(t.dst, set())
        if dst_terms & set(t.terms):
            raise ValueError(f"duplicate terms arriving at node {t.dst}: {t}")
        dst_terms.update(t.terms)

    def job_done(self, job_id: int) -> bool:
        j = self.jobs[job_id]
        return self.holdings[job_id].get(j.requestor) == set(j.full_terms)

    def all_done(self) -> bool:
        return all(self.job_done(j) for j in self.jobs)


# below this many transfers the object walk beats array compilation; the
# array fast path pays off on large (batched / machine-generated) plans
_FAST_VALIDATE_MIN_TRANSFERS = 64


def validate_plan(plan: RepairPlan, *, max_recv_per_round: int = 1,
                  fast: bool | None = None) -> None:
    """Structural invariants from the paper's constraints.

    * every transfer's payload is actually held at its source,
    * per round, each node plays at most one role (send xor receive xor
      relay) — the paper's one-link-per-node rule (`max_recv_per_round`
      relaxes receiving for fan-in schemes like traditional repair),
    * relays are used at most once per round and are not senders/receivers,
    * after the last round every job's requestor holds the full term set.

    Large plans take the array fast path (whole-plan bincount role checks
    + uint64 term-bitmask bookkeeping, see
    `repro.core.engine.arrays.validate_plan_arrays`); small plans, plans
    that cannot be lowered (helper/term ids >= 64), and `fast=False` use the
    object walk below. Both paths enforce identical invariants. Callers
    that already hold compiled `PlanArrays` (the vectorized engine)
    should call `validate_plan_arrays` directly and skip the re-compile.
    """
    if fast is None:
        fast = (sum(len(r.transfers) for r in plan.rounds)
                >= _FAST_VALIDATE_MIN_TRANSFERS)
    if fast:
        from repro.core.engine.arrays import (UnsupportedPlanError,
                                              compile_plan,
                                              validate_plan_arrays)

        try:
            arrays = compile_plan(plan)
        except UnsupportedPlanError:
            pass
        else:
            validate_plan_arrays(arrays, max_recv_per_round=max_recv_per_round)
            return
    state = FragmentState(plan.jobs)
    for rnd in plan.rounds:
        send_count: dict[int, int] = defaultdict(int)
        recv_count: dict[int, int] = defaultdict(int)
        relay_count: dict[int, int] = defaultdict(int)
        for t in rnd.transfers:
            send_count[t.src] += 1
            recv_count[t.dst] += 1
            for rl in t.relays:
                relay_count[rl] += 1
        for node, c in send_count.items():
            if c > 1:
                raise ValueError(f"node {node} sends {c} transfers in one round")
            if relay_count.get(node):
                raise ValueError(f"node {node} both sends and relays")
            if recv_count.get(node):
                raise ValueError(f"node {node} both sends and receives in a round")
        for node, c in recv_count.items():
            if c > max_recv_per_round:
                raise ValueError(f"node {node} receives {c} transfers in one round")
            if relay_count.get(node):
                raise ValueError(f"node {node} both receives and relays")
        for node, c in relay_count.items():
            if c > 1:
                raise ValueError(f"relay node {node} used {c} times in one round")
        for t in rnd.transfers:
            state.apply(t)
    if not state.all_done():
        raise ValueError("plan does not complete all jobs")
