"""Dynamic bandwidth process + concurrent-ingress degradation model.

Two empirical facts from the paper drive this module:

* Rapid change (hot storage): link bandwidths are re-drawn at a fixed
  interval — 5 s in the paper's "cold" simulation, 2 s in "hot" (Fig. 11).
  `BandwidthProcess` is a seeded piecewise-constant process with O(1)
  random access to any epoch (deterministic across runs and platforms).

* Fan-in degradation (Fig. 2): when m links send to one node concurrently,
  the *total* ingress throughput drops as m grows and the per-link split is
  uneven. `IngressModel` reproduces both effects; it is what penalizes
  star-repair and PPT's multi-sender assumption, exactly the paper's
  criticism.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class BandwidthProcess:
    """Piecewise-constant per-link scaling of a base matrix.

    In epoch e (t in [e*interval, (e+1)*interval)), each directed link's
    bandwidth depends on `mode`:
      * "jitter": base[i, j] * Uniform(1-jitter, 1+jitter) — load wobble
        around a stable mean (the paper's cold-storage regime),
      * "redraw": Uniform(min(base), max(base)) per link — memoryless
        stress case; no scheme can predict anything across epochs.
      * "markov": log-AR(1) around base — bw_e = base * exp(x_e),
        x_e = rho * x_{e-1} + sigma * sqrt(1-rho^2) * N(0,1). The paper's
        hot-storage regime: bandwidth "changes very sharply" yet links keep
        short-term memory, so a plan-once snapshot (PPT) decays over a few
        epochs while per-round monitoring (BMFRepair) stays current.
    Draws come from a counter-based rng keyed on (seed, epoch), so
    `matrix_at(t)` is pure and epoch-addressable without history.
    `change_interval=None` (or jitter=0 in jitter mode) freezes the network.
    """

    base: np.ndarray
    change_interval: float | None = None
    jitter: float = 0.5
    seed: int = 0
    min_bw: float = 0.5
    mode: str = "jitter"
    rho: float = 0.6      # markov: per-epoch correlation
    sigma: float = 0.5    # markov: stationary log-std
    _AR_HORIZON = 32      # markov: truncation (rho^32 ~ 1e-7 at rho=0.6)
    _CACHE_LIMIT = 128    # per-instance epoch-matrix memo bound

    def __post_init__(self):
        # Per-instance epoch -> matrix memo. The event loop queries
        # matrix_at many times per epoch (every hop/epoch event); caching
        # keeps those queries O(1) without changing any returned value.
        # The innovation memo serves the overlapping markov AR windows:
        # consecutive epochs share all but one N(0,1) draw, so caching
        # cuts epoch-matrix generation from O(horizon) to O(1) rng calls.
        # The AR-state memo does the same for the Horner recursion: while
        # the window still starts at epoch 0 (e <= horizon), x_e is exactly
        # x_{e-1} * rho + z_e, so one fused multiply-add replaces the
        # whole window walk — bit-identical by construction.
        object.__setattr__(self, "_epoch_cache", {})
        object.__setattr__(self, "_innov_cache", {})
        object.__setattr__(self, "_ar_cache", {})
        object.__setattr__(self, "_block_cache", {})
        object.__setattr__(self, "_prefix_cache", {})

    def epoch_of(self, t: float) -> int:
        if self.change_interval is None:
            return 0
        # math.floor(t / i) == int(np.floor(t / i)) for finite floats and
        # is an order of magnitude cheaper on the per-event hot path
        return math.floor(t / self.change_interval)

    def epoch_end(self, t: float) -> float:
        if self.change_interval is None:
            return np.inf
        return (self.epoch_of(t) + 1) * self.change_interval

    @property
    def num_nodes(self) -> int:
        return self.base.shape[0]

    def _innovation(self, e: int) -> np.ndarray:
        """Epoch e's N(0,1) draw (markov mode), keyed on (seed, epoch)."""
        z = self._innov_cache.get(e)
        if z is None:
            if len(self._innov_cache) >= 4 * self._CACHE_LIMIT:
                self._innov_cache.clear()
            rng = np.random.default_rng(np.random.SeedSequence([self.seed, e]))
            z = rng.standard_normal(self.base.shape)
            z.setflags(write=False)
            self._innov_cache[e] = z
        return z

    def _ar_state(self, e: int, innovations: dict[int, np.ndarray] | None) -> np.ndarray:
        """Markov AR state x_e, evaluated by the same Horner recursion the
        windowed sum has always used. While the truncation window still
        starts at epoch 0 (e <= horizon) the memoized previous state gives
        x_e = x_{e-1} * rho + z_e in one step — the identical float ops,
        just not recomputed from scratch each epoch."""

        def innov(i: int) -> np.ndarray:
            return innovations[i] if innovations is not None \
                else self._innovation(i)

        start = max(0, e - self._AR_HORIZON)
        if start == 0:
            cached = self._ar_cache.get(e)
            if cached is not None:
                return cached
            prev = self._ar_cache.get(e - 1) if e > 0 else None
            if prev is not None:
                x = prev * self.rho + innov(e)
            else:
                x = innov(0)
                for i in range(1, e + 1):
                    x = x * self.rho + innov(i)
            if len(self._ar_cache) >= 4 * self._CACHE_LIMIT:
                self._ar_cache.clear()
            x.setflags(write=False)
            self._ar_cache[e] = x
            return x
        x = innov(start)
        for i in range(start + 1, e + 1):
            x = x * self.rho + innov(i)
        return x

    def _epoch_matrix(self, e: int, innovations: dict[int, np.ndarray] | None = None) -> np.ndarray:
        """The epoch-e matrix, uncached. `innovations` optionally supplies
        precomputed markov draws (bit-identical to `_innovation`) so batch
        sampling avoids re-deriving the AR window per epoch."""
        if self.mode == "redraw":
            rng = np.random.default_rng(np.random.SeedSequence([self.seed, e]))
            off = ~np.eye(self.base.shape[0], dtype=bool)
            lo = float(self.base[off].min())
            hi = float(self.base[off].max())
            m = rng.uniform(lo, hi, self.base.shape)
        elif self.mode == "jitter":
            rng = np.random.default_rng(np.random.SeedSequence([self.seed, e]))
            scale = rng.uniform(1.0 - self.jitter, 1.0 + self.jitter, self.base.shape)
            m = self.base * scale
        elif self.mode == "markov":
            # exact log-AR(1) via truncated innovation sum (epoch-addressable):
            # x_e = sigma*sqrt(1-rho^2) * sum_{i} rho^(e-i) z_i,  z_i ~ N(0,1)
            x = self._ar_state(e, innovations)
            m = self.base * np.exp(self.sigma * np.sqrt(1 - self.rho**2) * x)
        else:
            raise ValueError(f"unknown bandwidth mode {self.mode!r}")
        m = np.maximum(m, self.min_bw)
        np.fill_diagonal(m, 0.0)
        return m

    def matrix_at(self, t: float) -> np.ndarray:
        """The bandwidth matrix active at time t.

        The return value may be a shared cache entry and is marked
        read-only — `.copy()` before doing in-place what-if math on it.
        """
        if self.change_interval is None:
            return self.base
        if self.mode == "jitter" and self.jitter == 0.0:
            return self.base
        e = self.epoch_of(t)
        cached = self._epoch_cache.get(e)
        if cached is None:
            if len(self._epoch_cache) >= self._CACHE_LIMIT:
                self._epoch_cache.clear()
            cached = self._epoch_matrix(e)
            cached.setflags(write=False)
            self._epoch_cache[e] = cached
        return cached

    def sample_epochs(self, num_epochs: int, *, start_epoch: int = 0) -> np.ndarray:
        """Batched sampling: the (num_epochs, N, N) stack of epoch matrices.

        Bit-identical to ``[matrix_at(e * interval) for e in epochs]`` but
        amortized: markov innovations are drawn once per epoch and shared
        across the overlapping AR windows (O(E) rng draws instead of
        O(E * horizon)), the AR states accumulate by the same one-step
        Horner recursion `_ar_state` uses, and the per-link math (exp,
        scale, clamp, diagonal) runs once over the whole (E, N, N) stack —
        elementwise, so each epoch's floats are exactly `matrix_at`'s.
        This is the bulk-sampling substrate for the sweep engine, the
        batched engine's live-epoch prefetch, and `BandwidthTrace`
        recording.
        """
        if num_epochs < 0 or start_epoch < 0:
            raise ValueError("num_epochs and start_epoch must be >= 0")
        n = self.base.shape[0]
        if self.change_interval is None or (self.mode == "jitter" and self.jitter == 0.0):
            out = np.broadcast_to(self.base, (num_epochs, n, n)).copy()
            return out
        if self.mode == "markov" and num_epochs:
            x = np.empty((num_epochs, n, n))
            for j, e in enumerate(range(start_epoch, start_epoch + num_epochs)):
                x[j] = self._ar_state(e, None)
            out = self.base * np.exp(
                self.sigma * np.sqrt(1 - self.rho**2) * x)
            np.maximum(out, self.min_bw, out=out)
            out[:, np.arange(n), np.arange(n)] = 0.0
            return out
        out = np.empty((num_epochs, n, n), dtype=float)
        for j, e in enumerate(range(start_epoch, start_epoch + num_epochs)):
            out[j] = self._epoch_matrix(e)
        return out

    def epochs_prefix(self, num_epochs: int) -> np.ndarray:
        """Memoized read-only `(num_epochs, N, N)` prefix of the epoch
        sequence (epochs `[0, num_epochs)`), bit-identical to
        `sample_epochs(num_epochs)`.

        This is the bulk substrate for device-resident epoch stacks
        (`repro.core.engine.jax_stepper`): the stack is sampled once per
        process instance and shared across every scheme/batch that
        replays the same case, and a longer request *extends* the cached
        prefix in place of resampling it (`sample_epochs` is
        epoch-addressable, so the extension is the identical tail).
        """
        if num_epochs < 0:
            raise ValueError("num_epochs must be >= 0")
        have, stack = self._prefix_cache.get("prefix", (0, None))
        if stack is None or have < num_epochs:
            tail = self.sample_epochs(num_epochs - have, start_epoch=have)
            stack = tail if stack is None else np.concatenate([stack, tail])
            stack.setflags(write=False)
            self._prefix_cache["prefix"] = (num_epochs, stack)
        return stack[:num_epochs]

    _BLOCK_EPOCHS = 4

    def epochs_block(self, e: int) -> tuple[int, np.ndarray]:
        """The block-aligned `(start, (K, N, N))` stack covering epoch `e`.

        Blocks are `sample_epochs` slices aligned to multiples of
        `_BLOCK_EPOCHS` and memoized per instance, so consumers that walk
        epochs in order (the batched engine's bandwidth stack) amortize
        both the rng and the per-epoch wrapper across the block — and
        across repeated walks, e.g. one per scheme in a sweep.
        """
        start = (e // self._BLOCK_EPOCHS) * self._BLOCK_EPOCHS
        blk = self._block_cache.get(start)
        if blk is None:
            if len(self._block_cache) >= self._CACHE_LIMIT:
                self._block_cache.clear()
            blk = self.sample_epochs(self._BLOCK_EPOCHS, start_epoch=start)
            blk.setflags(write=False)
            self._block_cache[start] = blk
        return start, blk


@dataclasses.dataclass(frozen=True)
class BandwidthTrace:
    """Replay of recorded bandwidth epochs (same interface as
    `BandwidthProcess`: `epoch_of` / `epoch_end` / `matrix_at`).

    `epochs[e]` is the bandwidth matrix active during
    [e * interval, (e+1) * interval). Past the end of the recording the
    trace either cycles (default — stationary background churn) or holds
    the final epoch. Traces come from real measurements or from
    `record()`-ing a synthetic `BandwidthProcess`, which lets a sweep
    replay the *exact same* bandwidth sample path under every scheme and
    planner variant.
    """

    epochs: np.ndarray            # (E, N, N) recorded per-epoch matrices
    change_interval: float
    cycle: bool = True

    def __post_init__(self):
        ep = np.array(self.epochs, dtype=float)      # own + freeze: views of
        ep.setflags(write=False)                     # it are handed out below
        if ep.ndim != 3 or ep.shape[1] != ep.shape[2] or ep.shape[0] == 0:
            raise ValueError(f"epochs must be (E, N, N) with E >= 1, got {ep.shape}")
        if not self.change_interval or self.change_interval <= 0:
            raise ValueError("change_interval must be > 0")
        object.__setattr__(self, "epochs", ep)

    @classmethod
    def record(
        cls,
        process: BandwidthProcess,
        num_epochs: int,
        *,
        start_epoch: int = 0,
        cycle: bool = True,
        change_interval: float | None = None,
    ) -> "BandwidthTrace":
        """Snapshot `num_epochs` of a BandwidthProcess into a replayable trace."""
        interval = change_interval or process.change_interval
        if interval is None:
            interval = np.inf  # static process: one eternal epoch
            num_epochs = 1
        return cls(
            epochs=process.sample_epochs(num_epochs, start_epoch=start_epoch),
            change_interval=float(interval) if np.isfinite(interval) else 1e30,
            cycle=cycle,
        )

    @property
    def num_nodes(self) -> int:
        return self.epochs.shape[1]

    @property
    def num_epochs(self) -> int:
        return self.epochs.shape[0]

    def epoch_of(self, t: float) -> int:
        return math.floor(t / self.change_interval)

    def epoch_end(self, t: float) -> float:
        return (self.epoch_of(t) + 1) * self.change_interval

    def matrix_at(self, t: float) -> np.ndarray:
        e = self.epoch_of(t)
        if self.cycle:
            e = e % self.num_epochs
        else:
            e = min(e, self.num_epochs - 1)
        return self.epochs[e]


@dataclasses.dataclass(frozen=True)
class IngressModel:
    """Effective per-link rates when m senders target one receiver.

    Total usable ingress = (best single in-link bw) * g(m) with
    g(m) = max(floor, 1 - degrade*(m-1))  (Fig. 2: total trends *down*,
    ~-8%/link in the measurement), split unevenly by Dirichlet(alpha)
    weights (Fig. 2: shares are skewed). The split is *persistent* for the
    whole concurrent episode (keyed on receiver and fan-in, not time):
    Fig. 2 shows a slow flow staying slow, and the paper observes the
    resulting "wide fluctuation" of multi-sender schemes. Each link is
    additionally capped by its own standalone bandwidth; m=1 degenerates
    to the standalone rate.
    """

    degrade: float = 0.10
    floor: float = 0.40
    alpha: float = 1.0
    seed: int = 0
    persistent_shares: bool = True

    def total_factor(self, m: int) -> float:
        return max(self.floor, 1.0 - self.degrade * (m - 1))

    def share_weights(self, m: int, receiver: int, epoch: int) -> np.ndarray:
        """The Dirichlet split of `m` concurrent in-links at `receiver`.

        Keyed on (seed, receiver, m) — plus epoch when shares are not
        persistent — so the split is a pure function of the episode, not of
        when or how often it is queried. This is the single source of truth
        for both the per-event object engine (`effective_rates`) and the
        batched vectorized engine, which memoizes these vectors per batch.
        """
        if m <= 1:
            return np.ones(m)
        key = [self.seed, int(receiver), int(m)]
        if not self.persistent_shares:
            key.append(int(epoch))
        rng = np.random.default_rng(np.random.SeedSequence(key))
        return rng.dirichlet(np.full(m, self.alpha))

    def effective_rates(
        self,
        link_bws: np.ndarray,
        receiver: int,
        epoch: int,
    ) -> np.ndarray:
        """link_bws: standalone rates of the m concurrent in-links."""
        link_bws = np.asarray(link_bws, dtype=float)
        m = link_bws.size
        if m == 0:
            return link_bws
        if m == 1:
            return link_bws.copy()
        cap = float(link_bws.max()) * self.total_factor(m)
        w = self.share_weights(m, receiver, epoch)
        return np.minimum(link_bws, w * cap)

    # fraction of a link's rate retained when the node simultaneously moves
    # data in the other direction (pipelining rx+tx on one host; measured
    # "single node accessing multiple links" effect on ~2-vCPU cloud VMs)
    duplex: float = 0.65

    def node_allocations(
        self,
        link_bws: np.ndarray,
        directions: tuple[str, ...],
        node: int,
        epoch: int,
    ) -> np.ndarray:
        """Capacity split when one node drives m concurrent links.

        Links of the *same* direction contend like receiver fan-in
        (degraded total, persistent skewed split). If the node is active in
        *both* directions at once (a pipelined relay receiving from a child
        while sending to its parent — something BMF's store-and-forward
        relays never do), every allocation is further scaled by `duplex`.
        """
        link_bws = np.asarray(link_bws, dtype=float)
        out = np.zeros_like(link_bws)
        dirs = np.asarray(directions)
        for d in ("rx", "tx"):
            sel = dirs == d
            if sel.any():
                out[sel] = self.effective_rates(link_bws[sel], node, epoch)
        if (dirs == "rx").any() and (dirs == "tx").any():
            out = out * self.duplex
        return out
