"""The paper's contribution: BMFRepair (Alg. 1) + MSRepair (Alg. 2) and the
baselines they are evaluated against (traditional, PPR, PPT, m-PPR, random
scheduling), plus the dynamic-bandwidth simulator and JAX data-plane
executor. See DESIGN.md section 1/2."""

from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel  # noqa: F401
from repro.core.plan import Job, RepairPlan, Round, Transfer, validate_plan  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    ALL_SCHEMES,
    MULTI_SCHEMES,
    SINGLE_SCHEMES,
    RepairSimulator,
    Scenario,
    SimResult,
    run_scheme,
)
