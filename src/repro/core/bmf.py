"""BMFRepair (paper Algorithm 1) — bandwidth-aware multi-level forwarding.

Given one round's transfers and the *current* bandwidth matrix (BMFRepair
monitors bandwidth in real time and re-optimizes every round), repeatedly:

  1. find the transfer whose path takes the longest (round time = max),
  2. search the cheapest store-and-forward route src -> ... -> dst through
     still-unused *idle* nodes (pruned DFS; path cost = sum of hop times,
     per the paper's t21+t22 < t2 example; each idle node forwards once),
  3. if the route beats the current path, commit it and repeat; stop when
     the slowest transfer cannot be improved (paper's loop exit).

`optimize_all=True` is a beyond-paper extension: after the bottleneck stops
improving, also reroute non-bottleneck transfers (helps when bandwidth will
shift mid-round; disabled for paper-faithful runs).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import Round, Transfer


def path_time(path: tuple[int, ...], bw: np.ndarray, chunk_mb: float) -> float:
    """Store-and-forward: sum of hop times (paper Fig. 3/6 semantics)."""
    total = 0.0
    for u, v in zip(path[:-1], path[1:]):
        b = bw[u, v]
        if b <= 0:
            return float("inf")
        total += chunk_mb / b
    return total


def find_min_time_path(
    src: int,
    dst: int,
    idle,                       # iterable of idle node ids, order = DFS order
    bw: np.ndarray,
    chunk_mb: float,
    bound: float,
) -> tuple[tuple[int, ...], float]:
    """Pruned DFS over idle-node subsets (paper Fig. 6 tree search).

    Returns the best path and its time; (src, dst) direct if nothing beats
    `bound`. Partial sums >= the best known time are pruned — the paper's
    observation that this keeps the brute-force search ~3% of repair time.
    """
    best_path: tuple[int, ...] = (src, dst)
    best_time = min(bound, path_time(best_path, bw, chunk_mb))

    idle = [x for x in idle if x != src and x != dst]

    def dfs(cur: int, used: set[int], cost: float, route: list[int]) -> None:
        nonlocal best_path, best_time
        # option 1: hop straight to dst
        if bw[cur, dst] > 0:
            t = cost + chunk_mb / bw[cur, dst]
            if t < best_time:
                best_time = t
                best_path = tuple(route) + (dst,)
        # option 2: extend through an unused idle node
        for nxt in idle:
            if nxt in used or bw[cur, nxt] <= 0:
                continue
            c = cost + chunk_mb / bw[cur, nxt]
            if c >= best_time:  # prune (the paper's 4+5 > 5 example)
                continue
            used.add(nxt)
            route.append(nxt)
            dfs(nxt, used, c, route)
            route.pop()
            used.remove(nxt)

    dfs(src, {src}, 0.0, [src])
    return best_path, best_time


@dataclasses.dataclass
class BMFStats:
    iterations: int = 0
    improved_links: int = 0
    time_saved: float = 0.0            # total, accumulated in commit order
    time_saved_bottleneck: float = 0.0  # Alg. 1 bottleneck loop alone
    time_saved_extra: float = 0.0       # beyond-paper optimize_all pass


def optimize_round(
    rnd: Round,
    bw: np.ndarray,
    idle_nodes: list[int],
    chunk_mb: float,
    *,
    optimize_all: bool = False,
    max_iters: int = 64,
) -> tuple[Round, BMFStats]:
    """Algorithm 1 (BMFRepair) applied to one round's links.

    `time_saved` keeps the historical total; the bottleneck-loop and
    optimize-all contributions are also accounted separately
    (`time_saved_bottleneck` / `time_saved_extra`) so ablations can
    attribute the gain to the paper's loop vs the extension.
    """
    transfers = [
        Transfer(src=t.src, dst=t.dst, job=t.job, terms=t.terms, path=t.path)
        for t in rnd.transfers
    ]
    if not transfers:
        return Round(transfers=[]), BMFStats()
    in_use = set()
    for t in transfers:
        in_use.update(t.path)
    # dict-as-ordered-set: O(1) relay removal while preserving the caller's
    # idle order (the DFS child order, hence tie-breaking, depends on it)
    avail = {x: None for x in idle_nodes if x not in in_use}
    stats = BMFStats()

    def t_time(t: Transfer) -> float:
        return path_time(t.path, bw, chunk_mb)

    for _ in range(max_iters):
        stats.iterations += 1
        worst = max(transfers, key=t_time)
        worst_time = t_time(worst)
        path, new_time = find_min_time_path(
            worst.src, worst.dst, avail, bw, chunk_mb, worst_time
        )
        if new_time >= worst_time or path == worst.path:
            break  # the bottleneck link cannot be improved -> exit (Alg. 1)
        worst.path = path
        for relay in path[1:-1]:
            del avail[relay]
        stats.improved_links += 1
        stats.time_saved += worst_time - new_time
        stats.time_saved_bottleneck += worst_time - new_time

    if optimize_all:  # beyond-paper: also shorten non-bottleneck links
        for t in sorted(transfers, key=t_time, reverse=True):
            cur = t_time(t)
            path, new_time = find_min_time_path(t.src, t.dst, avail, bw, chunk_mb, cur)
            if new_time < cur and path != t.path:
                t.path = path
                for relay in path[1:-1]:
                    del avail[relay]
                stats.improved_links += 1
                stats.time_saved += cur - new_time
                stats.time_saved_extra += cur - new_time

    return Round(transfers=transfers), stats
