"""Cluster topology and bandwidth matrices.

The repair algorithms consume a directed bandwidth matrix BW[i, j] in MB/s
(paper notation "M/s"): the standalone rate of a single transfer i -> j.
Generators cover the paper's measured settings (Table I 4-node LAN, Table
III Aliyun 6-region WAN) plus synthetic heterogeneous clusters and a
TPU-pod-shaped ICI/DCN model for the checkpoint-repair deployment.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A set of storage nodes with named failure domains."""

    num_nodes: int
    names: tuple[str, ...] = ()

    def __post_init__(self):
        if self.names and len(self.names) != self.num_nodes:
            raise ValueError("names/num_nodes mismatch")

    def name(self, i: int) -> str:
        return self.names[i] if self.names else f"n{i + 1}"


# Paper Table I: measured LAN bandwidths (M/s) across nodes D3, P1, P2, P3.
TABLE1_NODES = ("D3", "P1", "P2", "P3")
TABLE1_BW = np.array(
    [
        # to:  D3   P1   P2   P3        from:
        [0.0, 4.0, 10.0, 7.0],        # D3
        [3.0, 0.0, 6.0, 8.0],         # P1
        [3.0, 10.0, 0.0, 5.0],        # P2
        [5.0, 5.0, 20.0, 0.0],        # P3
    ]
)

# Paper Table III: Aliyun ECS inter-region bandwidths (M/s).
ALIYUN_REGIONS = (
    "Beijing", "Zhangjiakou", "Shanghai", "Shenzhen", "HongKong", "Singapore"
)
ALIYUN_BW = np.array(
    [
        [0.0, 59.669, 39.587, 37.851, 32.156, 35.213],
        [67.321, 0.0, 44.126, 37.964, 22.315, 25.614],
        [35.123, 46.358, 0.0, 32.195, 36.665, 32.314],
        [25.674, 31.265, 34.321, 0.0, 59.362, 41.987],
        [26.646, 37.315, 32.158, 56.328, 0.0, 50.589],
        [20.347, 19.634, 21.365, 46.894, 38.234, 0.0],
    ]
)


def aliyun_matrix() -> tuple[Cluster, np.ndarray]:
    return Cluster(6, ALIYUN_REGIONS), ALIYUN_BW.copy()


def table1_matrix() -> tuple[Cluster, np.ndarray]:
    return Cluster(4, TABLE1_NODES), TABLE1_BW.copy()


def uniform_matrix(n: int, bw: float = 50.0) -> np.ndarray:
    m = np.full((n, n), float(bw))
    np.fill_diagonal(m, 0.0)
    return m


def heterogeneous_matrix(
    n: int, *, low: float = 5.0, high: float = 100.0, seed: int = 0
) -> np.ndarray:
    """Asymmetric uniform-random bandwidths, the paper's Mininet regime."""
    rng = np.random.default_rng(seed)
    m = rng.uniform(low, high, size=(n, n))
    np.fill_diagonal(m, 0.0)
    return m


def tpu_pod_dcn_matrix(
    hosts_per_pod: int,
    num_pods: int,
    *,
    intra_bw: float = 400.0,
    inter_bw: float = 25.0,
    seed: int = 0,
    jitter: float = 0.3,
) -> tuple[Cluster, np.ndarray]:
    """Host-level network for EC-checkpoint repair on a multi-pod TPU cluster.

    Intra-pod host links ride the pod's data-center fabric (fast, stable-ish);
    inter-pod links ride shared DCN (slow, contended -> the paper's rapidly-
    changing regime). Bandwidths are per-host-pair effective rates in MB/s.
    """
    n = hosts_per_pod * num_pods
    rng = np.random.default_rng(seed)
    m = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            base = intra_bw if (i // hosts_per_pod == j // hosts_per_pod) else inter_bw
            m[i, j] = base * (1.0 + jitter * rng.uniform(-1.0, 1.0))
    names = tuple(
        f"pod{p}/host{h}" for p in range(num_pods) for h in range(hosts_per_pod)
    )
    return Cluster(n, names), m
