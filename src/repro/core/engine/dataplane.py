"""Batched byte data plane: execute compiled `PlanArrays` over real bytes.

This is the array-native twin of `repro.core.executor.execute_plan` — the
module that *runs* a repair plan instead of timing it. Where the serial
oracle walks one plan's transfers with a dict of per-node device buffers
and one kernel call per chunk, this engine lowers a whole batch of
compiled plans into dense buffer tensors and executes every round as
three array steps:

1. **gather** — all of the round's payload rows, batch-wide, out of a
   `(B, S, nbytes)` buffer tensor (S = jobs x nodes slots; slot
   `j * N + v` is node v's buffer for job j);
2. **GF(256) premultiply** (init round only) — every helper chunk scaled
   by its repair coefficient in one `kernels.ops.gf256_scale_batch` call,
   with the coefficients themselves computed batched by
   `RSCode.repair_coeffs_batch` (one lockstep Gauss-Jordan per code);
3. **segment-XOR** — arrivals folded per (case, destination) group by one
   `kernels.ops.xor_reduce_segments` call and XOR-scattered back.

On TPU the two ops drive the Pallas kernel bodies over a grid (one
`pallas_call` per step instead of one per chunk); everywhere else they
fall back to the numpy oracles in `repro.kernels.ref`, so the batched
path stays a genuine throughput win on CPU too (`benchmarks/
bench_dataplane.py` gates it).

Execution semantics match the serial oracle exactly: within a round all
sources are consumed before any arrival lands (store-and-forward
two-phase), fan-in arrivals XOR-fold in transfer order (XOR is
associative, so the fold order cannot matter), relays re-send whole
buffers (`bytes_moved` counts `nbytes * (path_len - 1)` per transfer).
Like the oracle, the engine assumes a `validate_plan`-clean plan; the one
runtime invariant it re-checks is source occupancy — a transfer whose
source buffer was consumed in an earlier round raises `ValueError`
instead of silently moving zeros.

`block_of` decouples node ids from codeword positions: the simulator
convention (node i holds block i) is the identity default, while the
sweep's byte-verification layer passes the mapping of a *placed* stripe
(`repro.ec.stripe`), with plans relabeled through the placement by
`arrays.relabel_plan_nodes`.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

from repro.core.engine.arrays import PlanArrays, compile_plan
from repro.core.plan import RepairPlan
from repro.ec.rs import RSCode
from repro.kernels import ops


@dataclasses.dataclass
class BatchExecutionResult:
    """Per-case outcome of one batched data-plane run."""

    reconstructed: list[dict[int, np.ndarray]]   # per case: job_id -> bytes
    verified: np.ndarray                         # (B,) bool — every job exact
    bytes_moved: np.ndarray                      # (B,) int64

    @property
    def all_verified(self) -> bool:
        return bool(self.verified.all())


def identity_block_map(num_nodes: int, n: int) -> np.ndarray:
    """The simulator's placement: node i holds block i (i < n), -1 after."""
    out = np.full(max(num_nodes, n), -1, dtype=np.int64)
    out[:n] = np.arange(n)
    return out


def _as_plan_arrays(plans) -> list[PlanArrays]:
    return [p if isinstance(p, PlanArrays) else compile_plan(p)
            for p in plans]


def _repair_coeffs(
    pas: list[PlanArrays],
    codes: list[RSCode],
    block_maps: list[np.ndarray],
) -> list[np.ndarray]:
    """(k,)-coefficient rows for every (case, job), batched per code.

    Jobs of all cases sharing one (n, k) code go through a single
    `repair_coeffs_batch` call (one lockstep Gauss-Jordan), and identical
    (failed, helpers) rows within it are deduplicated — a 64-stripe batch
    repairing the same logical failure computes its coefficients once.
    """
    by_code: dict[tuple[int, int], list[tuple[int, int]]] = {}
    for b, (pa, code) in enumerate(zip(pas, codes)):
        for j in range(pa.num_jobs):
            by_code.setdefault((code.n, code.k), []).append((b, j))
    out: list[list] = [[None] * pa.num_jobs for pa in pas]
    for (n, k), rows in by_code.items():
        code = next(c for c in codes if (c.n, c.k) == (n, k))
        failed = np.empty(len(rows), dtype=np.int64)
        helpers = np.empty((len(rows), k), dtype=np.int64)
        for i, (b, j) in enumerate(rows):
            pa, bmap = pas[b], block_maps[b]
            hl = int(pa.job_helpers_len[j])
            if hl != k:
                raise ValueError(
                    f"job {int(pa.job_id[j])} has {hl} helpers, "
                    f"RS({n},{k}) repair needs exactly k")
            hb = bmap[pa.job_helpers[j, :k]]
            fb = bmap[pa.job_failed[j]]
            if fb < 0 or (hb < 0).any():
                raise ValueError(
                    f"job {int(pa.job_id[j])}: a failed/helper node holds "
                    "no block under the given placement")
            failed[i] = fb
            helpers[i] = hb
        uniq, inv = np.unique(
            np.concatenate([failed[:, None], helpers], axis=1),
            axis=0, return_inverse=True)
        coeffs = code.repair_coeffs_batch(uniq[:, 0], uniq[:, 1:])[inv]
        for i, (b, j) in enumerate(rows):
            out[b][j] = coeffs[i]
    return [np.stack(rows) if rows else np.zeros((0, 0), np.uint8)
            for rows in out]


def execute_plans_batch(
    plans: Sequence[PlanArrays | RepairPlan],
    codes: RSCode | Sequence[RSCode],
    codewords: np.ndarray | Sequence[np.ndarray],
    *,
    block_of: Sequence[np.ndarray | None] | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> BatchExecutionResult:
    """Execute a batch of repair plans over real bytes and verify them.

    `plans` are `PlanArrays` (or `RepairPlan`s, compiled on entry),
    `codes` one shared or per-case `RSCode`, `codewords` per-case
    `(n, nbytes)` uint8 block stacks (block-indexed; same nbytes across
    the batch). `block_of[b][node]` maps node ids to block positions
    (identity when None — the simulator convention). `use_kernel=None`
    compiles the Pallas kernels on TPU and runs the numpy ref path
    elsewhere (see `kernels.ops`). Returns per-case reconstructed bytes,
    a verified flag (every job's requestor buffer equals the lost block
    bit-for-bit) and relay-aware `bytes_moved` — byte-identical to
    running `executor.execute_plan` case by case.
    """
    pas = _as_plan_arrays(plans)
    B = len(pas)
    if B == 0:
        return BatchExecutionResult([], np.zeros(0, bool),
                                    np.zeros(0, np.int64))
    codes = list(codes) if isinstance(codes, Sequence) else [codes] * B
    cws = [np.asarray(cw, dtype=np.uint8) for cw in codewords]
    if len(codes) != B or len(cws) != B:
        raise ValueError("plans, codes and codewords must align")
    nbytes = cws[0].shape[-1]
    if any(cw.shape[-1] != nbytes for cw in cws):
        raise ValueError("all codewords must share one chunk size")
    N = max(pa.num_nodes for pa in pas)
    block_maps = []
    for b, pa in enumerate(pas):
        bmap = None if block_of is None else block_of[b]
        if bmap is None:
            bmap = identity_block_map(max(N, codes[b].n), codes[b].n)
        else:
            bmap = np.asarray(bmap, dtype=np.int64)
            if bmap.size < N:
                bmap = np.concatenate(
                    [bmap, np.full(N - bmap.size, -1, dtype=np.int64)])
        block_maps.append(bmap)
    jmax = max(pa.num_jobs for pa in pas)
    S = jmax * N
    buf = np.zeros((B, S, nbytes), dtype=np.uint8)
    occupied = np.zeros((B, S), dtype=bool)

    # ---- init: batched coefficients + one batched premultiply
    coeffs = _repair_coeffs(pas, codes, block_maps)
    tb, tslot, tcoef, tdata = [], [], [], []
    for b, pa in enumerate(pas):
        for j in range(pa.num_jobs):
            hl = int(pa.job_helpers_len[j])
            hs = pa.job_helpers[j, :hl].astype(np.int64)
            tb.extend([b] * hl)
            tslot.extend(j * N + hs)
            tcoef.extend(coeffs[b][j])
            tdata.append(cws[b][block_maps[b][hs]])
    if tb:
        pre = np.asarray(ops.gf256_scale_batch(
            np.asarray(tcoef, dtype=np.uint8), np.concatenate(tdata),
            use_kernel=use_kernel, interpret=interpret), dtype=np.uint8)
        buf[np.asarray(tb), np.asarray(tslot)] = pre
        occupied[np.asarray(tb), np.asarray(tslot)] = True

    # ---- flat round-major transfer table across the batch
    fb = np.concatenate([np.full(pa.num_transfers, b, dtype=np.int64)
                         for b, pa in enumerate(pas)])
    fround = np.concatenate([
        np.repeat(np.arange(pa.num_rounds, dtype=np.int64),
                  np.diff(pa.round_start)) for pa in pas])
    fsrc = np.concatenate([pa.t_job_idx.astype(np.int64) * N + pa.t_src
                           for pa in pas])
    fdst = np.concatenate([pa.t_job_idx.astype(np.int64) * N + pa.t_dst
                           for pa in pas])
    fhops = np.concatenate([pa.t_path_len.astype(np.int64) - 1
                            for pa in pas])

    bytes_moved = np.zeros(B, dtype=np.int64)
    np.add.at(bytes_moved, fb, nbytes * fhops)

    R = max((pa.num_rounds for pa in pas), default=0)
    for r in range(R):
        rows = np.nonzero(fround == r)[0]
        if not rows.size:
            continue
        rb, rsrc, rdst = fb[rows], fsrc[rows], fdst[rows]
        if not occupied[rb, rsrc].all():
            bad = int(np.nonzero(~occupied[rb, rsrc])[0][0])
            raise ValueError(
                f"round {r}: case {int(rb[bad])} transfer sources slot "
                f"(job {int(rsrc[bad]) // N}, node {int(rsrc[bad]) % N}) "
                "which holds no buffer — consumed in an earlier round? "
                "execute_plans_batch requires a validate_plan-clean plan")
        payload = buf[rb, rsrc]                      # gather (T_r, nbytes)
        buf[rb, rsrc] = 0                            # two-phase consume
        occupied[rb, rsrc] = False
        # fan-in groups per (case, destination slot), transfer order kept
        key = rb * S + rdst
        order = np.argsort(key, kind="stable")
        skey = key[order]
        boundary = np.empty(order.size, dtype=bool)
        boundary[0] = True
        np.not_equal(skey[1:], skey[:-1], out=boundary[1:])
        starts = np.nonzero(boundary)[0]
        counts = np.diff(np.append(starts, order.size))
        groups = np.full((starts.size, int(counts.max())), -1, dtype=np.int64)
        pos = np.arange(order.size) - np.repeat(starts, counts)
        groups[np.repeat(np.arange(starts.size), counts), pos] = order
        folded = np.asarray(ops.xor_reduce_segments(
            payload, groups, use_kernel=use_kernel, interpret=interpret),
            dtype=np.uint8)
        gkey = skey[starts]
        gb, gs = gkey // S, gkey % S
        buf[gb, gs] ^= folded                        # zeros when unoccupied
        occupied[gb, gs] = True

    # ---- verify every job's requestor buffer against the lost block
    recon: list[dict[int, np.ndarray]] = [dict() for _ in range(B)]
    verified = np.ones(B, dtype=bool)
    for b, pa in enumerate(pas):
        for j in range(pa.num_jobs):
            slot = j * N + int(pa.job_requestor[j])
            got = buf[b, slot].copy()
            recon[b][int(pa.job_id[j])] = got
            fblock = int(block_maps[b][pa.job_failed[j]])
            if not (occupied[b, slot]
                    and np.array_equal(got, cws[b][fblock])):
                verified[b] = False
    return BatchExecutionResult(reconstructed=recon, verified=verified,
                                bytes_moved=bytes_moved)
