"""Array-native planner layer: batched BMF path search + tuple schedulers.

Two families live here, both pinned bit-identical to the object planners:

* **Batched BMF (paper Algorithm 1).** `find_min_time_paths_batch`
  re-expresses `repro.core.bmf.find_min_time_path`'s pruned DFS as
  vectorized candidate-path enumeration over a bounded relay depth: hop
  times `chunk_mb / bw` become a `(B, N, N)` tensor, every src→relays→dst
  combination up to `max_relays` is priced in one broadcast sum, and a
  single `argmin` over the candidates — laid out in the DFS's exact
  pre-order, so ties break identically — reroutes the bottleneck transfer
  of *every* case in a batch at once. Exactness beyond the depth bound is
  certified by a min-plus Bellman-Ford sweep over the idle subgraph (with
  positive hop times the optimal relay route is a shortest simple path);
  the rare case whose optimum is deeper than the bound falls back to the
  scalar DFS. `optimize_round_batch` wraps the search in Algorithm 1's
  monitor-and-replan loop (bottleneck argmax, avail-mask bookkeeping,
  optional optimize-all pass), operating directly on the engine's
  `(B, T, H)` hop arrays — this is what lets `engine.vectorized` replan
  every round *inside* the batched stepper instead of dropping to
  per-case Python.

* **Tuple schedulers.** `msrepair_schedule` / `random_schedule` /
  `ppr_schedule` / `traditional_schedule` re-implement the round planners
  on uint-style term bitmasks (plain Python ints, so node ids >= 64 still
  work) and `(src, dst, job, mask)` tuples — no `Transfer`/`Round`/
  `FragmentState` allocation on the hot path. MSRepair's per-pick
  candidate recomputation collapses to one sorted scan per priority
  class: a commit only mutates holdings at nodes that just became busy,
  so the remaining candidates' keys, order and usefulness are unchanged
  (the random scheduler's within-round draw sequence survives the same
  way — filtering the snapshot equals recomputing it; across rounds its
  rng is counter-keyed on `(seed, round)`, see
  `RANDOM_SCHEDULE_VERSION`). `repro.core.msrepair` is now a thin object
  facade over these. `plan_arrays_for_scheme` lowers a schedule straight
  to `PlanArrays` for the vectorized engine.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.bmf import find_min_time_path
from repro.core.engine.arrays import (PlanArrays, UnsupportedPlanError,
                                      plan_arrays_from_schedule)
from repro.core.plan import Job

# one transfer tuple: (src, dst, job_id, terms_mask)
Sched = list[list[tuple[int, int, int, int]]]


def hop_time_stack(bw_stack: np.ndarray, chunk_mb: np.ndarray) -> np.ndarray:
    """`(B, N, N)` per-hop transfer times: chunk_mb / bw, inf where bw <= 0
    (matching `bmf.path_time`'s unreachable-hop semantics)."""
    B, N, _ = bw_stack.shape
    w = np.full((B, N, N), np.inf)
    np.divide(chunk_mb[:, None, None], bw_stack, out=w, where=bw_stack > 0)
    return w


def batched_path_times(
    hop_u: np.ndarray,          # (B, T, H) int
    hop_v: np.ndarray,          # (B, T, H) int
    n_hops: np.ndarray,         # (B, T) int — 0 marks padding
    w: np.ndarray,              # (B, N, N) hop times
) -> np.ndarray:
    """Per-transfer path times (B, T); padding transfers get -inf so they
    never win the bottleneck argmax. Hop times add left-to-right, the same
    association order as `bmf.path_time`."""
    B, T, H = hop_u.shape
    bi = np.arange(B)[:, None, None]
    hw = w[bi, hop_u, hop_v]
    valid = np.arange(H)[None, None, :] < n_hops[:, :, None]
    times = np.where(valid, hw, 0.0).sum(axis=2)
    return np.where(n_hops > 0, times, -np.inf)


# ----------------------------------------------------- batched path search
def find_min_time_paths_batch(
    src: np.ndarray,            # (B,) int
    dst: np.ndarray,            # (B,) int
    avail: np.ndarray,          # (B, N) bool — usable idle nodes
    w: np.ndarray,              # (B, N, N) hop times
    bound: np.ndarray,          # (B,) float
    *,
    bw_stack: np.ndarray | None = None,   # for the scalar DFS fallback
    chunk_mb: np.ndarray | None = None,
    max_relays: int = 3,
) -> tuple[list[tuple[int, ...]], np.ndarray, np.ndarray]:
    """Batched twin of `bmf.find_min_time_path` — exact, including ties.

    Enumerates every relay route up to `max_relays` deep as one broadcast
    cost tensor and takes a first-wins `argmin` whose flattened order is
    the DFS pre-order (hop-to-dst before extending, relays in ascending
    idle order), so equal-cost routes resolve to the same path the scalar
    search returns. A converged min-plus Bellman-Ford over the idle
    subgraph certifies the depth bound: positive hop times make the
    optimum a shortest simple path, so if the converged distance beats the
    bounded enumeration a deeper route exists and that case falls back to
    the scalar DFS (`bw_stack`/`chunk_mb` must then be provided).

    Returns `(paths, times, improved)`; non-improved cases report the
    direct path and `min(bound, direct)`, mirroring the DFS contract.
    """
    B, N, _ = w.shape
    bidx = np.arange(B)
    avail = avail.copy()
    avail[bidx, src] = False
    avail[bidx, dst] = False
    counts = avail.sum(axis=1)
    direct = w[bidx, src, dst]
    cap = np.minimum(bound, direct)
    M = int(counts.max()) if B else 0

    def _direct(b):
        return (int(src[b]), int(dst[b]))

    if M == 0:
        return ([_direct(b) for b in range(B)], cap.copy(),
                np.zeros(B, dtype=bool))

    # available node ids, ascending, padded to M; every hop touching a
    # padding slot costs inf so no route can pass through one
    order = np.argsort(~avail, axis=1, kind="stable")
    idle = order[:, :M]
    valid = np.arange(M)[None, :] < counts[:, None]
    bi = bidx[:, None]
    A = np.where(valid, w[bi, src[:, None], idle], np.inf)   # src -> relay
    C = np.where(valid, w[bi, idle, dst[:, None]], np.inf)   # relay -> dst
    B2 = np.where(valid[:, :, None] & valid[:, None, :],
                  w[bi[:, :, None], idle[:, :, None], idle[:, None, :]],
                  np.inf)

    # Candidate tensor indexed (q1, q2, q3), q = 0 meaning "stop here",
    # q >= 1 meaning relay idle[q - 1]. Flattened C-order == DFS pre-order:
    # direct, (r0), (r0,r1), (r0,r1,r2), ..., (r1), ... Invalid slots
    # (repeats, stop-then-relay) stay inf and can never win the argmin.
    if max_relays > 3:
        raise ValueError("max_relays > 3 not supported by the enumerator")
    Q = M + 1
    cand = np.full((B, Q, Q, Q), np.inf)
    cand[:, 0, 0, 0] = direct
    d1 = A + C
    cand[:, 1:, 0, 0] = d1
    best2 = np.minimum(direct, d1.min(axis=1))
    minw = w.min(axis=(1, 2))
    if max_relays >= 2 and M >= 2:
        d2 = (A[:, :, None] + B2) + C[:, None, :]
        d2[:, np.eye(M, dtype=bool)] = np.inf
        cand[:, 1:, 1:, 0] = d2
        best2 = np.minimum(best2, d2.min(axis=(1, 2)))
    # depth 3 is the expensive block (M^3 candidates) — price it only for
    # cases where a 3-relay route (>= 4 hops, each >= the cheapest hop)
    # could still beat *or tie* the depth-<=2 optimum and the caller's
    # bound (<=, not <: on an exact tie the DFS pre-order may prefer the
    # deeper route, so it must be enumerated)
    if max_relays >= 3 and M >= 3:
        rows = np.nonzero((counts >= 3)
                          & (4.0 * minw <= np.minimum(best2, cap)))[0]
        if rows.size:
            Ar, Br, Cr = A[rows], B2[rows], C[rows]
            d3 = (((Ar[:, :, None, None] + Br[:, :, :, None])
                   + Br[:, None, :, :]) + Cr[:, None, None, :])
            ii = np.arange(M)
            rep = ((ii[:, None, None] == ii[None, :, None])
                   | (ii[None, :, None] == ii[None, None, :])
                   | (ii[:, None, None] == ii[None, None, :]))
            d3[:, rep] = np.inf
            cand[rows, 1:, 1:, 1:] = d3
    flat = cand.reshape(B, -1)
    best = flat.argmin(axis=1)
    btime = flat[bidx, best]

    # Exactness certificate. Cheap bound first: a route deeper than 3
    # relays has >= 5 hops, each costing at least the case's cheapest hop,
    # so when 5 * min(w) cannot beat the enumerated optimum no deeper
    # route can either. Only cases failing that bound (and with enough
    # idle nodes to even form one) pay for the Bellman-Ford sweep —
    # converged min-plus shortest distances through the idle subgraph,
    # with the same left-to-right hop-sum association as the enumeration.
    target = np.minimum(btime, cap)
    suspect = (counts > max_relays) & ((max_relays + 2.0) * minw <= target)
    deeper = np.zeros(B, dtype=bool)
    if suspect.any():
        sus = np.nonzero(suspect)[0]
        ws = w[sus]
        av = avail[sus]
        dist = ws[np.arange(sus.size), src[sus]].copy()
        for _ in range(N):
            du = np.where(av, dist, np.inf)
            nd = np.minimum(dist, (du[:, :, None] + ws).min(axis=1))
            if np.array_equal(nd, dist):
                break
            dist = nd
        deeper[sus] = dist[np.arange(sus.size), dst[sus]] < target[sus]

    improved = btime < cap
    paths: list[tuple[int, ...]] = []
    times = np.where(improved, btime, cap)
    for b in range(B):
        if deeper[b]:
            if bw_stack is None or chunk_mb is None:
                raise ValueError(
                    "optimum deeper than max_relays and no bw_stack/chunk_mb "
                    "given for the scalar fallback")
            idle_list = [int(x) for x in np.nonzero(avail[b])[0]]
            path, t = find_min_time_path(
                int(src[b]), int(dst[b]), idle_list, bw_stack[b],
                float(chunk_mb[b]), float(bound[b]))
            paths.append(path)
            times[b] = t
            improved[b] = t < cap[b] and path != _direct(b)
            continue
        if not improved[b]:
            paths.append(_direct(b))
            continue
        q, rest = divmod(int(best[b]), Q * Q)
        q2, q3 = divmod(rest, Q)
        relays = tuple(int(idle[b, qq - 1]) for qq in (q, q2, q3) if qq > 0)
        paths.append((int(src[b]), *relays, int(dst[b])))
    return paths, times, improved


# ------------------------------------------------------ batched Algorithm 1
@dataclasses.dataclass
class BatchBMFStats:
    """Per-case `bmf.BMFStats` twin, accumulated in commit order so the
    `time_saved` floats match the scalar loop exactly."""

    iterations: np.ndarray
    improved_links: np.ndarray
    time_saved: np.ndarray
    time_saved_bottleneck: np.ndarray
    time_saved_extra: np.ndarray


def _set_path(hop_u, hop_v, n_hops, b, t, path):
    """Write `path`'s hops into row (b, t), widening H if needed."""
    nh = len(path) - 1
    H = hop_u.shape[2]
    if nh > H:
        pad = ((0, 0), (0, 0), (0, nh - H))
        hop_u = np.pad(hop_u, pad)
        hop_v = np.pad(hop_v, pad)
    hop_u[b, t, :nh] = path[:-1]
    hop_v[b, t, :nh] = path[1:]
    hop_u[b, t, nh:] = 0
    hop_v[b, t, nh:] = 0
    n_hops[b, t] = nh
    return hop_u, hop_v


def optimize_round_batch(
    hop_u: np.ndarray,          # (B, T, H) int
    hop_v: np.ndarray,          # (B, T, H) int
    n_hops: np.ndarray,         # (B, T) int — 0 marks padding
    bw_stack: np.ndarray,       # (B, N, N)
    chunk_mb: np.ndarray,       # (B,)
    avail: np.ndarray,          # (B, N) bool — mutated in place
    *,
    optimize_all: bool = False,
    max_iters: int = 64,
) -> tuple[np.ndarray, np.ndarray, BatchBMFStats,
           list[tuple[int, int, tuple[int, ...]]]]:
    """Algorithm 1 (BMFRepair) on one round of a whole batch of cases.

    The scalar loop's structure is kept case for case — bottleneck argmax
    (first max wins, like `max(key=...)`), reroute on strict improvement
    only, avail shrinks and never returns — but each iteration reroutes
    the bottleneck of *every still-improving case* with one batched path
    search. Returns the (possibly widened) hop arrays, per-case stats and
    the `(case, round_row, path)` splices applied, for write-back into
    each case's `PlanArrays`.
    """
    B, T, _ = hop_u.shape
    stats = BatchBMFStats(*(np.zeros(B, dtype=np.int64) for _ in range(2)),
                          *(np.zeros(B) for _ in range(3)))
    changed: list[tuple[int, int, tuple[int, ...]]] = []
    if T == 0:
        return hop_u, hop_v, stats, changed
    w = hop_time_stack(bw_stack, chunk_mb)
    times = batched_path_times(hop_u, hop_v, n_hops, w)
    active = (n_hops > 0).any(axis=1)

    def commit(b, t, path, saved, extra):
        nonlocal hop_u, hop_v
        hop_u, hop_v = _set_path(hop_u, hop_v, n_hops, b, t, path)
        for relay in path[1:-1]:
            avail[b, relay] = False
        stats.improved_links[b] += 1
        stats.time_saved[b] += saved
        if extra:
            stats.time_saved_extra[b] += saved
        else:
            stats.time_saved_bottleneck[b] += saved
        changed.append((b, t, path))

    for _ in range(max_iters):
        idx = np.nonzero(active)[0]
        if not idx.size:
            break
        stats.iterations[idx] += 1
        worst = times[idx].argmax(axis=1)
        wt = times[idx, worst]
        src = hop_u[idx, worst, 0]
        dst = hop_v[idx, worst, n_hops[idx, worst] - 1]
        paths, ptimes, improved = find_min_time_paths_batch(
            src, dst, avail[idx], w[idx], wt,
            bw_stack=bw_stack[idx], chunk_mb=chunk_mb[idx])
        for j, b in enumerate(idx):
            if not improved[j]:
                active[b] = False     # bottleneck can't improve -> exit
                continue
            commit(int(b), int(worst[j]), paths[j],
                   float(wt[j]) - float(ptimes[j]), extra=False)
            times[b, worst[j]] = ptimes[j]

    if optimize_all:   # beyond-paper pass, batched by descending-time rank
        rank_order = np.argsort(-times, axis=1, kind="stable")
        arange_b = np.arange(B)
        for rank in range(T):
            tr = rank_order[:, rank]
            idx = np.nonzero(n_hops[arange_b, tr] > 0)[0]
            if not idx.size:
                continue
            tj = tr[idx]
            cur = times[idx, tj]
            src = hop_u[idx, tj, 0]
            dst = hop_v[idx, tj, n_hops[idx, tj] - 1]
            paths, ptimes, improved = find_min_time_paths_batch(
                src, dst, avail[idx], w[idx], cur,
                bw_stack=bw_stack[idx], chunk_mb=chunk_mb[idx])
            for j, b in enumerate(idx):
                if improved[j]:
                    commit(int(b), int(tj[j]), paths[j],
                           float(cur[j]) - float(ptimes[j]), extra=True)
                    times[b, tj[j]] = ptimes[j]

    return hop_u, hop_v, stats, changed


# --------------------------------------------------------- tuple schedulers
def _terms_mask_any(ids) -> int:
    """Term bitmask as an unbounded Python int (ids >= 64 allowed — only
    the `PlanArrays` lowering requires uint64)."""
    mask = 0
    for x in ids:
        mask |= 1 << int(x)
    return mask


def traditional_schedule(job: Job) -> Sched:
    """Star repair: every helper streams straight to the requestor."""
    return [[(h, job.requestor, job.job_id, 1 << h) for h in job.helpers]]


# binomial-tree transfer pattern per helper count k, over *positions*
# 0..k (0 = requestor): rounds of (src_pos, dst_pos, term_positions).
# Structural — independent of node ids — so it is computed once per k.
_PPR_PATTERNS: dict[int, list[list[tuple[int, int, tuple[int, ...]]]]] = {}


def _ppr_pattern(k: int) -> list[list[tuple[int, int, tuple[int, ...]]]]:
    pattern = _PPR_PATTERNS.get(k)
    if pattern is None:
        hold: dict[int, set[int]] = {p: {p} for p in range(1, k + 1)}
        pattern = []
        num_rounds = math.ceil(math.log2(k + 1)) if k > 0 else 0
        for t in range(1, num_rounds + 1):
            stride = 1 << (t - 1)
            rnd = []
            for i in range(stride, k + 1, 2 * stride):
                frag = hold.get(i)
                if not frag:
                    continue
                del hold[i]
                hold.setdefault(i - stride, set()).update(frag)
                rnd.append((i, i - stride, tuple(sorted(frag))))
            if rnd:
                pattern.append(rnd)
        assert hold.get(0, set()) == set(range(1, k + 1)), \
            "PPR schedule incomplete"
        _PPR_PATTERNS[k] = pattern
    return pattern


def ppr_schedule(job: Job) -> Sched:
    """PPR binomial-tree reduction (`repro.core.ppr.ppr_rounds` twin):
    the cached position pattern for k helpers, mapped to this job's
    node ids."""
    nodes = (job.requestor, *job.helpers)
    bits = [0, *(1 << h for h in job.helpers)]
    out: Sched = []
    for rnd in _ppr_pattern(len(job.helpers)):
        out.append([
            (nodes[i], nodes[j],
             job.job_id, sum(bits[p] for p in terms))
            for i, j, terms in rnd
        ])
    return out


def mppr_schedule(jobs: list[Job]) -> Sched:
    """m-PPR: each job's PPR schedule back-to-back (jobs serialize)."""
    rounds: Sched = []
    for job in jobs:
        rounds.extend(ppr_schedule(job))
    return rounds


class _MaskState:
    """Bitmask twin of `plan.FragmentState`: per-job insertion-ordered
    `{node: terms_mask}` dicts (same order semantics as the dict-of-set
    walk: delete removes, first merge appends at the end) plus an
    incrementally maintained per-node load (number of jobs holding there,
    the MSRepair tie-break key)."""

    def __init__(self, jobs: list[Job]):
        self.jobs = jobs
        self.req = {j.job_id: j.requestor for j in jobs}
        self.full = {j.job_id: _terms_mask_any(j.helpers) for j in jobs}
        self.hold: dict[int, dict[int, int]] = {
            j.job_id: {h: 1 << h for h in j.helpers} for j in jobs
        }
        self.load: dict[int, int] = {}
        for j in jobs:
            for h in j.helpers:
                self.load[h] = self.load.get(h, 0) + 1

    def job_done(self, job_id: int) -> bool:
        return self.hold[job_id].get(self.req[job_id]) == self.full[job_id]

    def all_done(self) -> bool:
        return all(self.job_done(j.job_id) for j in self.jobs)

    def apply(self, job_id: int, src: int, dst: int) -> int:
        """Move src's whole holding to dst; returns the mask moved."""
        row = self.hold[job_id]
        mask = row.pop(src)
        self.load[src] -= 1
        if dst in row:
            row[dst] |= mask
        else:
            row[dst] = mask
            self.load[dst] = self.load.get(dst, 0) + 1
        return mask


def _node_class(jobs: list[Job]) -> dict[int, str]:
    """Node -> R/NR/RP classification (paper eqs. 1-3)."""
    helper_sets = [set(j.helpers) for j in jobs]
    r = set.intersection(*helper_sets) if helper_sets else set()
    nr = set.union(*helper_sets) - r if helper_sets else set()
    out: dict[int, str] = {}
    for x in nr:
        out[x] = "NR"
    for x in r:
        out[x] = "R"
    for j in jobs:       # RP wins, as in the object `set_of`
        out[j.requestor] = "RP"
    return out


_PRIORITY = (("R", "R"), ("R", "NR"), ("NR", "RP"), ("NR", "NR"),
             ("R", "RP"), ("NR", "R"))


def msrepair_schedule(jobs: list[Job], *, max_rounds: int = 64) -> Sched:
    """MSRepair (paper Algorithm 2) on bitmask state.

    Identical schedule to the historical object walk, but each priority
    class computes its candidate list *once*: a commit only touches
    holdings at the two nodes it marks busy, so the surviving candidates'
    sort keys (load, job, src, dst), usefulness and payload masks are
    exactly what a recompute would return — one sorted scan per class
    replaces the per-pick O(candidates) rebuild. (Candidate *enumeration*
    order is free here — the sort key is total — unlike
    `random_schedule`, which must preserve it.)
    """
    cls_of = _node_class(jobs)
    state = _MaskState(jobs)
    load = state.load
    rounds: Sched = []
    for _ in range(max_rounds):
        if state.all_done():
            break
        busy: set[int] = set()
        rnd: list[tuple[int, int, int, int]] = []
        for s_cls, d_cls in _PRIORITY:
            cands = []
            for job in jobs:
                job_id = job.job_id
                if state.job_done(job_id):
                    continue
                req = state.req[job_id]
                holders = state.hold[job_id]
                dsts = [d for d in (*holders, req)
                        if cls_of.get(d, "IDLE") == d_cls]
                if not dsts:
                    continue
                for src in holders:
                    if (src in busy or src == req
                            or cls_of.get(src, "IDLE") != s_cls):
                        continue
                    nload = -load[src]
                    cands.extend(
                        (nload, job_id, src, dst) for dst in dsts
                        if dst != src and dst not in busy
                        and (dst == req or dst in holders))
            cands.sort()
            for _, job_id, src, dst in cands:
                if src in busy or dst in busy or state.job_done(job_id):
                    continue
                mask = state.apply(job_id, src, dst)
                rnd.append((src, dst, job_id, mask))
                busy.update((src, dst))
        if not rnd:
            raise RuntimeError("MSRepair stalled — no feasible transfer")
        rounds.append(rnd)
    else:
        raise RuntimeError("MSRepair exceeded max_rounds")
    return rounds


def msrepair_schedule_batch(jobs_list: list[list[Job]],
                            *, max_rounds: int = 64) -> list[Sched]:
    """MSRepair for a whole batch of cases in lockstep array ops.

    One (B, J, N) uint64 holdings tensor carries every case's fragment
    state; each priority class prices all cases' candidates as one
    (B, J, N, N) mask with an integer key encoding the tuple scheduler's
    exact sort order ((-load, job, src, dst) — load frozen at class
    start), and the greedy commit scan picks each case's min-key valid
    candidate per iteration. Schedules are identical to
    `msrepair_schedule` case for case (the parity tests pin this); cases
    that don't fit the array form (node ids >= 64, or more jobs than
    helpers pad) fall back to the tuple scheduler individually.
    """
    B = len(jobs_list)
    out: list[Sched | None] = [None] * B
    ok: list[int] = []
    for i, jobs in enumerate(jobs_list):
        ids = [x for j in jobs for x in (j.requestor, *j.helpers)]
        if all(0 <= x < 64 for x in ids):
            ok.append(i)
        else:
            out[i] = msrepair_schedule(jobs_list[i], max_rounds=max_rounds)
    if not ok:
        return out

    Bk = len(ok)
    J = max(len(jobs_list[i]) for i in ok)
    N = max(x for i in ok for j in jobs_list[i]
            for x in (j.requestor, *j.helpers)) + 1
    hold = np.zeros((Bk, J, N), dtype=np.uint64)
    full = np.zeros((Bk, J), dtype=np.uint64)
    req = np.zeros((Bk, J), dtype=np.int64)
    job_valid = np.zeros((Bk, J), dtype=bool)
    job_ids = np.zeros((Bk, J), dtype=np.int64)
    # node class codes matching the tuple scheduler's R/NR/RP/IDLE
    CLS = {"R": 0, "NR": 1, "RP": 2, "IDLE": 3}
    cls = np.full((Bk, N), CLS["IDLE"], dtype=np.int8)
    for k, i in enumerate(ok):
        jobs = jobs_list[i]
        ncls = _node_class(jobs)
        for node, name in ncls.items():
            cls[k, node] = CLS[name]
        for j, job in enumerate(jobs):
            job_valid[k, j] = True
            job_ids[k, j] = job.job_id
            req[k, j] = job.requestor
            full[k, j] = _terms_mask_any(job.helpers)
            for h in job.helpers:
                hold[k, j, h] = np.uint64(1) << np.uint64(h)

    nodes = np.arange(N)
    not_self = ~np.eye(N, dtype=bool)
    is_req = nodes[None, None, :] == req[:, :, None]         # (B, J, N)
    scheds: list[list[list]] = [[] for _ in range(Bk)]
    bidx = np.arange(Bk)

    def done_jobs():
        at_req = np.take_along_axis(hold, req[:, :, None], axis=2)[:, :, 0]
        return (at_req == full) | ~job_valid

    for _ in range(max_rounds):
        done = done_jobs()
        active = ~done.all(axis=1)
        if not active.any():
            break
        busy = np.zeros((Bk, N), dtype=bool)
        rnd: list[list[list]] = [[] for _ in range(Bk)]
        for s_code, d_code in ((CLS[a], CLS[b]) for a, b in _PRIORITY):
            holds = hold != 0
            load = holds.sum(axis=1).astype(np.int64)        # (B, N)
            live_job = (~done & job_valid)[:, :, None]
            src_ok = (holds & live_job & ~is_req
                      & (cls[:, None, :] == s_code) & ~busy[:, None, :])
            dst_ok = ((holds | is_req) & live_job
                      & (cls[:, None, :] == d_code) & ~busy[:, None, :])
            cand = (src_ok[:, :, :, None] & dst_ok[:, :, None, :]
                    & not_self[None, None, :, :] & active[:, None, None, None])
            if not cand.any():
                continue
            # key encodes the tuple sort (-load[src], job, src, dst):
            # unique per (job, src, dst), so argmin is exactly the scan
            key = ((((J - load)[:, None, :, None] * J
                     + np.arange(J)[None, :, None, None]) * N
                    + nodes[None, None, :, None]) * N
                   + nodes[None, None, None, :])
            big_key = np.iinfo(np.int64).max
            fk = np.where(cand, key, big_key).reshape(Bk, -1)
            fk4 = fk.reshape(Bk, J, N, N)
            while True:
                pick = fk.argmin(axis=1)
                rows = np.nonzero(fk[bidx, pick] < big_key)[0]
                if not rows.size:
                    break
                pick = pick[rows]
                j, rem = np.divmod(pick, N * N)
                s, d = np.divmod(rem, N)
                moved = hold[rows, j, s]
                hold[rows, j, s] = 0
                hold[rows, j, d] |= moved
                busy[rows, s] = True
                busy[rows, d] = True
                for r, jj, ss, dd, mm in zip(rows, j, s, d, moved):
                    rnd[r].append((int(ss), int(dd),
                                   int(job_ids[r, jj]), int(mm)))
                # invalidate: newly-busy nodes, and jobs just completed
                nb = np.zeros((Bk, N), dtype=bool)
                nb[rows, s] = True
                nb[rows, d] = True
                np.copyto(fk4, big_key, where=nb[:, None, :, None])
                np.copyto(fk4, big_key, where=nb[:, None, None, :])
                now_done = np.take_along_axis(
                    hold[rows, j], req[rows, j][:, None], axis=1
                )[:, 0] == full[rows, j]
                if now_done.any():
                    dr = rows[now_done]
                    done[dr, j[now_done]] = True
                    jd = np.zeros((Bk, J), dtype=bool)
                    jd[dr, j[now_done]] = True
                    np.copyto(fk4, big_key, where=jd[:, :, None, None])
        committed = np.array([len(rnd[k]) > 0 for k in range(Bk)])
        if (active & ~committed).any():
            raise RuntimeError("MSRepair stalled — no feasible transfer")
        for k in np.nonzero(committed)[0]:
            scheds[k].append(rnd[k])
    else:
        if (~done_jobs().all(axis=1)).any():
            raise RuntimeError("MSRepair exceeded max_rounds")

    for k, i in enumerate(ok):
        out[i] = scheds[k]
    return out


# Version of the random-baseline schedule semantics. v1 drew every round
# from ONE shared `default_rng(seed)` stream and enumerated candidates in
# holdings-insertion order — draw r's value depended on every earlier
# round, so rounds (and cases) could never be scheduled independently.
# v2 keys each round's rng on the counter `(seed, round)` and enumerates
# candidates in sorted `(job, src, dst)` order: rounds are pure functions
# of `(seed, round, holdings)`, the exact property a lockstep batched
# scheduler (like `msrepair_schedule_batch`) needs. Schedules differ from
# v1 — `tests/test_planner_arrays.py` pins the v2 expectation explicitly.
RANDOM_SCHEDULE_VERSION = 2


def random_schedule(jobs: list[Job], *, seed: int = 0,
                    max_rounds: int = 256) -> Sched:
    """Random-baseline scheduler (v2 — see `RANDOM_SCHEDULE_VERSION`).

    Each round draws from a counter-based rng keyed on `(seed, round)`
    (the per-case seed comes in through `seed`), so a round's draws are
    independent of every other round and case. The candidate list is
    enumerated once per round in sorted `(job, src, dst)` order and
    filtered after each commit — a commit only invalidates candidates
    touching the two newly-busy nodes (and the job it may complete), so
    the filtered list matches a recompute element for element and the
    `rng.integers(len(cands))` draw sequence within the round is
    well-defined.
    """
    state = _MaskState(jobs)
    rounds: Sched = []
    for r in range(max_rounds):
        if state.all_done():
            break
        rng = np.random.default_rng(np.random.SeedSequence([seed, r]))
        busy: set[int] = set()
        rnd: list[tuple[int, int, int, int]] = []
        cands = []
        for job in jobs:
            job_id = job.job_id
            if state.job_done(job_id):
                continue
            req = state.req[job_id]
            holders = state.hold[job_id]
            dsts = (*holders, req)
            cands.extend(
                (job_id, src, dst)
                for src in holders if src != req
                for dst in dsts
                if dst != src and (dst == req or dst in holders))
        cands.sort()
        while cands:
            job_id, src, dst = cands[int(rng.integers(len(cands)))]
            mask = state.apply(job_id, src, dst)
            rnd.append((src, dst, job_id, mask))
            busy.update((src, dst))
            # only the two newly-busy nodes and (possibly) the committed
            # job's done-ness can invalidate surviving candidates
            drop_job = job_id if state.job_done(job_id) else None
            cands = [
                c for c in cands
                if c[1] != src and c[1] != dst and c[2] != src
                and c[2] != dst and c[0] != drop_job
            ]
        if not rnd:
            raise RuntimeError("random scheduler stalled")
        rounds.append(rnd)
    else:
        raise RuntimeError("random scheduler exceeded max_rounds")
    return rounds


# --------------------------------------------------------- PlanArrays exit
def schedule_for_scheme(scheme: str, jobs: list[Job], *,
                        random_seed: int = 0) -> tuple[list[Job], Sched, dict]:
    """Run `scheme`'s tuple scheduler: `(jobs_used, schedule, meta)`."""
    if scheme == "traditional":
        return jobs[:1], traditional_schedule(jobs[0]), \
            {"scheme": "traditional"}
    if scheme in ("ppr", "bmf", "bmf_static"):
        return jobs[:1], ppr_schedule(jobs[0]), {"scheme": "ppr"}
    if scheme == "mppr":
        return jobs, mppr_schedule(jobs), {"scheme": "m-ppr"}
    if scheme == "random":
        return jobs, random_schedule(jobs, seed=random_seed), \
            {"scheme": "random"}
    if scheme == "msrepair":
        return jobs, msrepair_schedule(jobs), {"scheme": "msrepair"}
    raise ValueError(f"unknown scheme {scheme!r}")


def plan_arrays_for_scheme(scheme: str, jobs: list[Job], *,
                           random_seed: int = 0) -> PlanArrays:
    """Plan `scheme` straight into `PlanArrays` (the vectorized engine's
    native input), bypassing object `RepairPlan` construction entirely.
    `decompile` of the result equals `simulator.plan_for_scheme`'s plan.
    Raises `UnsupportedPlanError` when term ids don't fit uint64 masks."""
    jobs, sched, meta = schedule_for_scheme(scheme, jobs,
                                            random_seed=random_seed)
    return plan_arrays_from_schedule(jobs, sched, meta)


def lower_schedules_batch(
    items: list[tuple[list[Job], Sched, dict]],
    *,
    max_recv_per_round=1,      # int, or one int per item (fan-in schemes)
) -> list[PlanArrays | None]:
    """Lower + validate a whole batch of schedules in one array pass.

    The per-case `plan_arrays_from_schedule` + `validate_plan_arrays`
    pair costs mostly numpy-call overhead at these plan sizes; here all
    cases' transfers (and jobs) are lowered through ONE concatenated
    array, each case's `PlanArrays` receiving views of the shared
    buffers, and role exclusivity is checked for the whole batch with
    three bincounts over (case, round, node) keys. Scheduler output is
    all-direct (relays are spliced in later by the in-stepper BMF), so
    the relay role checks are vacuous here; the per-case fragment walk
    runs on the shared python lists. A case that cannot be lowered
    (term ids >= 64) comes back as None; a case that fails validation
    raises the same `ValueError` the per-case path raises.
    """
    from repro.core.engine.arrays import (_case_plan_arrays, _job_fields,
                                          _mask_terms)

    B = len(items)
    out: list[PlanArrays | None] = [None] * B
    ok: list[int] = []
    flats: list[list] = []
    for idx, (jobs, sched, meta) in enumerate(items):
        job_ids = {j.job_id for j in jobs}
        flat = [tr for rnd in sched for tr in rnd]
        if any(tr[3] >> 64 or tr[2] not in job_ids for tr in flat) or any(
                not 0 <= h < 64 for j in jobs for h in j.helpers):
            flats.append(None)
        else:
            ok.append(idx)
            flats.append(flat)
    if not ok:
        return out

    big = [tr for f in flats if f is not None for tr in f]
    tarr = np.array(big, dtype=np.uint64).reshape(len(big), 4)
    ints = tarr[:, :3].astype(np.int32)
    jobs_all = [j for i in ok for j in items[i][0]]
    jf = _job_fields(jobs_all)

    t_off = j_off = 0
    offsets = []
    for i in ok:
        jobs, sched, meta = items[i]
        flat = flats[i]
        nt, nj = len(flat), len(jobs)
        sl, jl = slice(t_off, t_off + nt), slice(j_off, j_off + nj)
        out[i] = _case_plan_arrays(
            jobs, sched, flat, meta,
            {k: v[jl] for k, v in jf.items()},
            ints[sl], tarr[sl, 3],
        )
        offsets.append((i, t_off, nt))
        t_off += nt
        j_off += nj

    # batched role exclusivity: one bincount per role over
    # (case-global round, node) keys; failures re-raise per case
    recv_lims = (max_recv_per_round if isinstance(max_recv_per_round, list)
                 else [max_recv_per_round] * B)
    n_max = max(out[i].num_nodes for i in ok)
    round_id = np.empty(t_off, dtype=np.int64)
    round_lim: list[int] = []
    base = 0
    for i, o, nt in offsets:
        sched = items[i][1]
        num_r = len(sched)
        round_id[o: o + nt] = base + np.repeat(
            np.arange(num_r, dtype=np.int64),
            [len(rnd) for rnd in sched])
        round_lim.extend([recv_lims[i]] * num_r)
        base += num_r
    size = base * n_max
    send_c = np.bincount(round_id * n_max + ints[:, 0], minlength=size)
    recv_c = np.bincount(round_id * n_max + ints[:, 1], minlength=size)
    recv_over = recv_c > np.repeat(np.array(round_lim, dtype=np.int64),
                                   n_max)
    if ((send_c > 1).any() or recv_over.any()
            or ((send_c > 0) & (recv_c > 0)).any()):
        from repro.core.engine.arrays import validate_plan_arrays

        for i in ok:   # slow path: find the culprit, raise its error
            validate_plan_arrays(out[i], max_recv_per_round=recv_lims[i])

    # fragment walk per case over the shared python lists
    srcs = ints[:, 0].tolist()
    dsts = ints[:, 1].tolist()
    terms = tarr[:, 3].tolist()
    for i, o, nt in offsets:
        pa = out[i]
        jobs = items[i][0]
        hold = [{h: 1 << h for h in j.helpers} for j in jobs]
        jidx = pa.t_job_idx.tolist()
        for k in range(nt):
            j, s, d, sent = jidx[k], srcs[o + k], dsts[o + k], terms[o + k]
            row = hold[j]
            held = row.get(s, 0)
            if held == 0 or held != sent:
                raise ValueError(
                    f"transfer {s}->{d} (job {int(pa.t_job[k])}) sends "
                    f"terms not matching src holding "
                    f"(held={sorted(_mask_terms(held))}, "
                    f"sent={sorted(_mask_terms(sent))})")
            row[s] = 0
            have = row.get(d, 0)
            if have & sent:
                raise ValueError(
                    f"duplicate terms arriving at node {d}: "
                    f"{sorted(_mask_terms(have & sent))}")
            row[d] = have | sent
        for j, job in enumerate(jobs):
            if hold[j].get(job.requestor, 0) != _terms_mask_any(job.helpers):
                raise ValueError("plan does not complete all jobs")
    return out
