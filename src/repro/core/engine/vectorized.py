"""Batched vectorized round/pipeline engine over `PlanArrays`.

This is the array-native twin of `repro.core.simulator`: instead of one
Python event loop per scenario, a whole *batch* of scenarios advances
together through masked `(B, ...)` state arrays. Every case still takes
exactly the event steps it would take alone — each case has its own
`dt`, epoch boundary and completion mask — so per-case results match the
object engine (same float ops in the same order — bit-identical in
practice; the parity tests pin 1e-6 relative); only the bookkeeping
between events is vectorized:

* fan-in contention groups become a stable sort + segment reductions
  (`np.maximum.reduceat`) instead of per-receiver dict building, with
  Dirichlet share vectors (`IngressModel.share_weights`) memoized per
  (case, receiver, fan-in) across the whole batch instead of redrawn
  every event;
* PPT's recursive `supply_rate` becomes an iterative topological
  min-scan over edge-depth levels (`np.minimum.at` scatters);
* epoch flips refresh a per-case `(B, N, N)` bandwidth stack only when a
  case actually crosses its epoch boundary.

Planning (the schemes' Python planners and per-round BMF re-optimization)
stays per-case object code — it is ~3% of repair time (paper Fig. 8) and
is where the paper's "monitor + replan every timestamp" logic lives. The
`(B, ...)` layout is the seam a future `jax.vmap`/Pallas stepper plugs
into: the inner loop is already pure array math over static shapes.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time

import numpy as np

from repro.core import bmf
from repro.core.engine.arrays import (PlanArrays, UnsupportedPlanError,
                                      compile_plan, validate_plan_arrays)
from repro.core.plan import RepairPlan, Round
from repro.core.ppt import build_ppt_tree
from repro.core.simulator import (Scenario, SimResult, _idle_pool,
                                  pipeline_fill_latency, plan_for_scheme,
                                  run_scheme)

_EPS = 1e-9
_GUARD = 100_000
_MISSING = object()


# ------------------------------------------------------------ batch context
class _BatchBandwidth:
    """Per-case `(B, N, N)` bandwidth stack, refreshed on epoch crossings.

    `BandwidthTrace` cases (the bulk `sample_epochs` recordings from
    `TraceSuite.freeze`) index the recorded epoch stack directly;
    everything else goes through `matrix_at`, whose per-instance epoch
    memo is shared with the object engine and across a case's schemes.
    Either way a case's matrix is reloaded only when its own epoch
    boundary passes — between epochs the stack row is reused as-is.
    """

    _DENSE_LIMIT_BYTES = 128 * 1024 * 1024

    def __init__(self, bwps, num_nodes: int):
        from repro.core.bandwidth import BandwidthTrace

        self.bwps = list(bwps)
        b = len(self.bwps)
        self.stack = np.zeros((b, num_nodes, num_nodes), dtype=float)
        self.epoch = np.zeros(b, dtype=np.int64)
        self.epoch_end = np.full(b, -np.inf)
        # per-case serving recipe: (interval, epochs, num_epochs, cycle)
        # for traces, None for everything served through matrix_at
        self._trace = [
            (bwp.change_interval, bwp.epochs, bwp.num_epochs, bwp.cycle)
            if type(bwp) is BandwidthTrace else None
            for bwp in self.bwps
        ]
        # all-trace batches get a padded (B, Emax, N, N) stack so a whole
        # refresh is one fancy gather instead of a per-case python loop
        self._dense = None
        if all(tr is not None for tr in self._trace) and b:
            emax = max(tr[2] for tr in self._trace)
            if b * emax * num_nodes * num_nodes * 8 <= self._DENSE_LIMIT_BYTES:
                dense = np.zeros((b, emax, num_nodes, num_nodes))
                for i, (_, epochs, num_e, _) in enumerate(self._trace):
                    n = epochs.shape[1]
                    dense[i, :num_e, :n, :n] = epochs
                self._dense = dense
                self._interval = np.array([tr[0] for tr in self._trace])
                self._num_epochs = np.array([tr[2] for tr in self._trace])
                self._cycle = np.array([tr[3] for tr in self._trace])

    def refresh(self, t: np.ndarray, active: np.ndarray) -> None:
        """Reload matrices for active cases whose epoch boundary passed."""
        crossed = active & (t >= self.epoch_end)
        if self._dense is not None:
            rows = np.nonzero(crossed)[0]
            if rows.size:
                # floor of true division == BandwidthTrace.epoch_of
                # (floor(t / i), NOT t // i — float floordiv is fmod-based
                # and can differ by one epoch at exact-multiple boundaries)
                e = np.floor(t[rows] / self._interval[rows]).astype(np.int64)
                idx = np.where(self._cycle[rows], e % self._num_epochs[rows],
                               np.minimum(e, self._num_epochs[rows] - 1))
                self.stack[rows] = self._dense[rows, idx]
                self.epoch[rows] = e
                self.epoch_end[rows] = (e + 1) * self._interval[rows]
            return
        for b in np.nonzero(crossed)[0]:
            tb = float(t[b])
            trace = self._trace[b]
            if trace is not None:
                interval, epochs, num_epochs, cycle = trace
                e = math.floor(tb / interval)   # == epoch_of(tb)
                self.epoch[b] = e
                self.epoch_end[b] = (e + 1) * interval
                self.stack[b] = epochs[e % num_epochs if cycle
                                       else min(e, num_epochs - 1)]
            else:
                bwp = self.bwps[b]
                self.epoch[b] = bwp.epoch_of(tb)
                self.epoch_end[b] = bwp.epoch_end(tb)
                self.stack[b] = bwp.matrix_at(tb)


def _group_structure(
    b_idx: np.ndarray,
    recv: np.ndarray,
    epoch: np.ndarray,
    num_nodes: int,
    ingresses,
    degrade: np.ndarray,
    floor: np.ndarray,
    wcache: dict,
):
    """Precompute the fan-in grouping of concurrent (case, link) pairs.

    Returns None when every receiver has a single sender (m = 1
    degenerates to the standalone rate), else the sort order, segment
    starts, per-pair Dirichlet shares and per-group degradation factors.
    Reusable across event steps for as long as the *set* of concurrent
    pairs is unchanged (rates then vary only through the bandwidth
    matrices) and shares are persistent.
    """
    n = b_idx.size
    key = b_idx * num_nodes + recv
    order = np.argsort(key, kind="stable")
    skey = key[order]
    boundary = np.empty(n, dtype=bool)
    boundary[0] = True
    np.not_equal(skey[1:], skey[:-1], out=boundary[1:])
    starts = np.nonzero(boundary)[0]
    if starts.size == n:
        return None
    counts = np.diff(np.append(starts, n))
    gkey = skey[starts]
    gb = gkey // num_nodes
    factor = np.maximum(floor[gb], 1.0 - degrade[gb] * (counts - 1))

    w = np.ones(n)
    reusable = True
    for gi in np.nonzero(counts > 1)[0]:
        b, m = int(gb[gi]), int(counts[gi])
        v = int(gkey[gi]) % num_nodes
        ing = ingresses[b]
        if ing.persistent_shares:
            ck = (b, v, m)
        else:
            ck = (b, v, m, int(epoch[b]))
            reusable = False     # shares re-drawn per epoch: don't reuse
        ww = wcache.get(ck)
        if ww is None:
            ww = ing.share_weights(m, v, int(epoch[b]))
            wcache[ck] = ww
        w[starts[gi]: starts[gi] + m] = ww
    return order, starts, counts, factor, w, reusable


def _contended_rates_grouped(structure, standalone: np.ndarray) -> np.ndarray:
    """Apply a precomputed fan-in grouping to current standalone rates.

    Same arithmetic as `IngressModel.effective_rates` per group:
    cap = max(group) * factor(m), eff = min(standalone, share * cap).
    """
    if structure is None:
        return standalone
    order, starts, counts, factor, w, _ = structure
    sval = standalone[order]
    cap = np.maximum.reduceat(sval, starts) * factor
    eff = np.empty(sval.size)
    eff[order] = np.minimum(sval, w * np.repeat(cap, counts))
    return eff


# ------------------------------------------------------------- round engine
def execute_round_batch(
    hop_u: np.ndarray,           # (B, T, H) int, -1 padded
    hop_v: np.ndarray,           # (B, T, H) int
    n_hops: np.ndarray,          # (B, T) int — 0 marks padding transfers
    t0: np.ndarray,              # (B,) float
    bb: _BatchBandwidth,
    ingresses,
    chunk_mb: np.ndarray,        # (B,) float
    wcache: dict,
    degrade: np.ndarray,
    floor: np.ndarray,
) -> np.ndarray:
    """Advance every case until all its round transfers complete.

    The masked-array twin of `simulator.execute_round`: one iteration =
    one event (hop completion or epoch flip) *per active case*, all cases
    stepping concurrently, each by its own `dt`.
    """
    B, T, _ = hop_u.shape
    num_nodes = bb.stack.shape[1]
    t = np.asarray(t0, dtype=float).copy()
    if T == 0:
        return t
    hop_i = np.zeros((B, T), dtype=np.int64)
    left = np.broadcast_to(chunk_mb[:, None], (B, T)).copy()
    chunk_col = chunk_mb[:, None]
    eps_chunk = _EPS * chunk_col
    done = (hop_i >= n_hops).all(axis=1)
    iters = 0
    rates = np.zeros((B, T))
    cand = np.empty((B, T))
    # the (case, transfer) -> current-hop structure only changes when a hop
    # completes; between completions (i.e. across pure epoch-flip events)
    # the fan-in grouping and Dirichlet shares are reused as-is
    pairs_dirty = True
    act = bi = ti = u = v = structure = None

    while not done.all():
        iters += 1
        if iters > _GUARD:
            raise RuntimeError("simulator failed to converge")
        bb.refresh(t, ~done)
        if pairs_dirty:
            act = (hop_i < n_hops) & ~done[:, None]
            bi, ti = np.nonzero(act)         # row-major: per-case transfer order
            h = hop_i[bi, ti]
            u = hop_u[bi, ti, h]
            v = hop_v[bi, ti, h]
            structure = _group_structure(
                bi, v, bb.epoch, num_nodes, ingresses, degrade, floor, wcache)
            # non-persistent shares are epoch-keyed: rebuild every event
            pairs_dirty = structure is not None and not structure[5]
        eff = _contended_rates_grouped(structure, bb.stack[bi, u, v])
        rates.fill(0.0)
        rates[bi, ti] = np.maximum(eff, 0.0)

        cand.fill(np.inf)
        np.divide(left, rates, out=cand, where=act & (rates > 0))
        dt = np.minimum(bb.epoch_end - t, cand.min(axis=1))
        dt[~np.isfinite(dt) | (dt <= 0)] = _EPS
        dt[done] = 0.0

        rates *= dt[:, None]
        np.subtract(left, rates, out=left, where=act)
        t += dt
        compl = act & (left <= eps_chunk)
        if compl.any():
            hop_i += compl
            np.copyto(left, chunk_col, where=compl)
            done = (hop_i >= n_hops).all(axis=1)
            pairs_dirty = True
    return t


def _hops_from_rounds(rounds: list[Round]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad one round's transfers (per case) into (B, T, H) hop arrays."""
    B = len(rounds)
    T = max((len(r.transfers) for r in rounds), default=0)
    H = max((len(tr.path) - 1 for r in rounds for tr in r.transfers),
            default=1)
    hop_u = np.full((B, max(T, 1), max(H, 1)), -1, dtype=np.int64)
    hop_v = np.full_like(hop_u, -1)
    n_hops = np.zeros((B, max(T, 1)), dtype=np.int64)
    for b, rnd in enumerate(rounds):
        for i, tr in enumerate(rnd.transfers):
            nh = len(tr.path) - 1
            hop_u[b, i, :nh] = tr.path[:-1]
            hop_v[b, i, :nh] = tr.path[1:]
            n_hops[b, i] = nh
    # padding hops index node 0 so fancy-indexing stays in bounds; they are
    # masked out by n_hops == 0 / hop_i >= n_hops before any rate math
    np.maximum(hop_u, 0, out=hop_u)
    np.maximum(hop_v, 0, out=hop_v)
    return hop_u, hop_v, n_hops


# ---------------------------------------------------------- pipeline engine
@dataclasses.dataclass
class _PipelinePrep:
    tree: object
    t_start: float
    plan_clock: float


def execute_pipeline_batch(
    child: np.ndarray,           # (B, E) int — 0-padded, dead via left == 0
    parent: np.ndarray,          # (B, E) int
    depth: np.ndarray,           # (B, E) int — child-node depth, 0 on padding
    edge_valid: np.ndarray,      # (B, E) bool
    t0: np.ndarray,              # (B,) float
    bb: _BatchBandwidth,
    ingresses,
    chunk_mb: np.ndarray,        # (B,) float
    wcache: dict,
    degrade: np.ndarray,
    floor: np.ndarray,
    duplex: np.ndarray,          # (B,) float
) -> np.ndarray:
    """Masked-array twin of `simulator.execute_pipeline`'s event loop.

    The recursive `supply_rate` (slowest live edge in the subtree feeding
    each node) is an iterative topological min-scan: edges are processed
    by descending child depth, scattering each edge's effective rate into
    its parent's supply cell with `np.minimum.at`.
    """
    B, E = child.shape
    num_nodes = bb.stack.shape[1]
    t = np.asarray(t0, dtype=float).copy()
    left = np.where(edge_valid, chunk_mb[:, None], 0.0)
    live = left > _EPS * chunk_mb[:, None]
    iters = np.zeros(B, dtype=np.int64)
    dmax = int(depth.max()) if depth.size else 0
    # live-edge structure (fan-in groups, duplex factors) changes only
    # when an edge drains; reuse it across pure epoch-flip events
    edges_dirty = True
    bi = ei = c = p = structure = rx_dup = tx_dup = None

    while live.any():
        case_on = live.any(axis=1)
        iters[case_on] += 1
        if iters.max() > _GUARD:
            raise RuntimeError("pipeline simulation failed to converge")
        bb.refresh(t, case_on)

        if edges_dirty:
            bi, ei = np.nonzero(live)        # row-major: per-case edge order
            c = child[bi, ei]
            p = parent[bi, ei]
            # rx fan-in contention at each parent; tx groups are singletons
            structure = _group_structure(
                bi, p, bb.epoch, num_nodes, ingresses, degrade, floor, wcache)
            has_rx = np.zeros((B, num_nodes), dtype=bool)
            has_rx[bi, p] = True
            has_tx = np.zeros((B, num_nodes), dtype=bool)
            has_tx[bi, c] = True
            rx_dup = np.where(has_tx[bi, p], duplex[bi], 1.0)
            tx_dup = np.where(has_rx[bi, c], duplex[bi], 1.0)
            edges_dirty = structure is not None and not structure[5]
        s = bb.stack[bi, c, p]
        rx_alloc = _contended_rates_grouped(structure, s) * rx_dup
        tx_alloc = s * tx_dup
        raw = np.minimum(np.maximum(rx_alloc, 0.0), np.maximum(tx_alloc, 0.0))
        raw_full = np.zeros((B, E))
        raw_full[bi, ei] = raw

        # iterative topological min-scan, deepest edges first
        node_supply = np.full((B, num_nodes), np.inf)
        eff_edge = raw_full.copy()
        for d in range(dmax, 0, -1):
            sel = live & (depth == d)
            if not sel.any():
                continue
            sb, se = np.nonzero(sel)
            val = np.minimum(raw_full[sb, se],
                             node_supply[sb, child[sb, se]])
            eff_edge[sb, se] = val
            np.minimum.at(node_supply, (sb, parent[sb, se]), val)
        rates = np.where(live, eff_edge, 0.0)

        cand = np.full((B, E), np.inf)
        np.divide(left, rates, out=cand, where=live & (rates > 0))
        dt = np.minimum(bb.epoch_end - t, cand.min(axis=1))
        dt = np.where(~np.isfinite(dt) | (dt <= 0), _EPS, dt)
        dt = np.where(case_on, dt, 0.0)

        left = np.where(live, left - rates * dt[:, None], left)
        t = t + dt
        new_live = left > _EPS * chunk_mb[:, None]
        if not np.array_equal(new_live, live):
            edges_dirty = True
        live = new_live
    return t


# ----------------------------------------------------------- batched scheme
def _ingress_params(scenarios):
    degrade = np.array([sc.ingress.degrade for sc in scenarios], dtype=float)
    floor = np.array([sc.ingress.floor for sc in scenarios], dtype=float)
    duplex = np.array([sc.ingress.duplex for sc in scenarios], dtype=float)
    return degrade, floor, duplex


def _chunk_array(scenarios) -> np.ndarray:
    # chunk_mb may arrive as python ints (benchmark grids use [8, 16, 32]);
    # the batched state math must stay float64
    return np.array([sc.chunk_mb for sc in scenarios], dtype=float)


def _run_ppt_batch(scenarios: list[Scenario]) -> list[SimResult]:
    B = len(scenarios)
    num_nodes = max(sc.num_nodes for sc in scenarios)
    preps: list[_PipelinePrep] = []
    for sc in scenarios:
        tic = _time.perf_counter()
        tree = build_ppt_tree(sc.make_jobs()[0], sc.bw.matrix_at(0.0))
        plan_clock = _time.perf_counter() - tic
        t_start = pipeline_fill_latency(tree, sc.bw.matrix_at(0.0),
                                        sc.chunk_mb)
        preps.append(_PipelinePrep(tree=tree, t_start=t_start,
                                   plan_clock=plan_clock))

    E = max(len(p.tree.parent) for p in preps)
    child = np.zeros((B, E), dtype=np.int64)
    parent = np.zeros((B, E), dtype=np.int64)
    depth_arr = np.zeros((B, E), dtype=np.int64)
    edge_valid = np.zeros((B, E), dtype=bool)
    for b, p in enumerate(preps):
        depths = p.tree.depths()
        for e, (c, par) in enumerate(p.tree.parent.items()):
            child[b, e] = c
            parent[b, e] = par
            depth_arr[b, e] = depths[c]
            edge_valid[b, e] = True

    bb = _BatchBandwidth([sc.bw for sc in scenarios], num_nodes)
    degrade, floor, duplex = _ingress_params(scenarios)
    chunk = _chunk_array(scenarios)
    t0 = np.array([p.t_start for p in preps])
    t_end = execute_pipeline_batch(
        child, parent, depth_arr, edge_valid, t0, bb,
        [sc.ingress for sc in scenarios], chunk, {}, degrade, floor, duplex,
    )
    return [
        SimResult(
            scheme="ppt", total_time=float(t_end[b]),
            round_times=[float(t_end[b])], planning_time=preps[b].plan_clock,
            plan=None, log=[f"ppt tree edges={preps[b].tree.edges}"],
        )
        for b in range(B)
    ]


def _run_rounds_batch(
    scenarios: list[Scenario],
    scheme: str,
    plans: list[RepairPlan],
    arrays: list[PlanArrays],
    jobs_list,
    plan_clocks: list[float],
    *,
    bmf_optimize_all: bool,
) -> list[SimResult]:
    B = len(scenarios)
    R = plans[0].num_rounds
    num_nodes = max(max(sc.num_nodes, pa.num_nodes)
                    for sc, pa in zip(scenarios, arrays))
    use_bmf = scheme in ("bmf", "msrepair", "bmf_static")
    static_plan_time = scheme == "bmf_static"

    bb = _BatchBandwidth([sc.bw for sc in scenarios], num_nodes)
    degrade, floor, _ = _ingress_params(scenarios)
    ingresses = [sc.ingress for sc in scenarios]
    chunk = _chunk_array(scenarios)
    wcache: dict = {}

    t = np.zeros(B)
    round_times: list[list[float]] = [[] for _ in range(B)]
    relay_hops = [0] * B
    logs: list[list[str]] = [[] for _ in range(B)]
    executed: list[list[Round]] = [[] for _ in range(B)]
    plan_clock = list(plan_clocks)

    for r in range(R):
        rounds_b: list[Round] = []
        for b in range(B):
            rnd = plans[b].rounds[r]
            if use_bmf:
                sc = scenarios[b]
                tic = _time.perf_counter()
                bw_now = sc.bw.matrix_at(0.0 if static_plan_time
                                         else float(t[b]))
                idle = [x for x in _idle_pool(sc, jobs_list[b])
                        if x not in rnd.nodes_in_use()]
                rnd, stats = bmf.optimize_round(
                    rnd, bw_now, idle, sc.chunk_mb,
                    optimize_all=bmf_optimize_all,
                )
                plan_clock[b] += _time.perf_counter() - tic
                relay_hops[b] += sum(len(tr.relays) for tr in rnd.transfers)
                if stats.improved_links:
                    logs[b].append(
                        f"t={float(t[b]):.2f}s round {r}: BMF rerouted "
                        f"{stats.improved_links} link(s), "
                        f"est -{stats.time_saved:.2f}s"
                    )
            rounds_b.append(rnd)
            executed[b].append(rnd)

        if use_bmf:
            hop_u, hop_v, n_hops = _hops_from_rounds(rounds_b)
        else:
            # offline schemes execute the compiled plan arrays directly
            per = [pa.round_hops(r) for pa in arrays]
            T = max(p[0].shape[0] for p in per)
            H = max(p[0].shape[1] for p in per)
            hop_u = np.zeros((B, max(T, 1), max(H, 1)), dtype=np.int64)
            hop_v = np.zeros_like(hop_u)
            n_hops = np.zeros((B, max(T, 1)), dtype=np.int64)
            for b, (hu, hv, nh) in enumerate(per):
                hop_u[b, : hu.shape[0], : hu.shape[1]] = np.maximum(hu, 0)
                hop_v[b, : hv.shape[0], : hv.shape[1]] = np.maximum(hv, 0)
                n_hops[b, : nh.shape[0]] = nh
        t_end = execute_round_batch(
            hop_u, hop_v, n_hops, t, bb, ingresses, chunk,
            wcache, degrade, floor,
        )
        for b in range(B):
            round_times[b].append(float(t_end[b] - t[b]))
        t = t_end

    return [
        SimResult(
            scheme=scheme, total_time=float(t[b]),
            round_times=round_times[b], planning_time=plan_clock[b],
            plan=RepairPlan(jobs=plans[b].jobs, rounds=executed[b],
                            meta=plans[b].meta),
            relay_hops=relay_hops[b], log=logs[b],
        )
        for b in range(B)
    ]


def run_scheme_vectorized(
    scenarios: list[Scenario],
    scheme: str,
    *,
    seeds: list[int] | None = None,
    bmf_optimize_all: bool = False,
) -> list[SimResult]:
    """Batched `run_scheme`: plan per case, execute in compatible batches.

    Cases are grouped by (cluster size, round count) — the structural
    compatibility the lockstep stepper needs — and each group runs through
    the batched engine; a case whose plan cannot be lowered to arrays
    falls back to the object engine. Results are returned in input order
    and match `run_scheme` case for case (modulo wall-clock
    `planning_time`). Because identical planner inputs are deduplicated,
    the returned `SimResult.plan`s may share objects across cases — copy
    before mutating (`run_sweep(keep_plans=True)` does this for you).
    """
    seeds = list(seeds) if seeds is not None else [0] * len(scenarios)
    if len(seeds) != len(scenarios):
        raise ValueError("seeds must match scenarios")
    results: list[SimResult | None] = [None] * len(scenarios)

    if scheme == "ppt":
        groups: dict[tuple, list[int]] = {}
        for i, sc in enumerate(scenarios):
            groups.setdefault((sc.num_nodes,), []).append(i)
        for idxs in groups.values():
            for i, r in zip(idxs, _run_ppt_batch([scenarios[i] for i in idxs])):
                results[i] = r
        return results

    prepared: dict[int, tuple] = {}
    fallback: list[int] = []
    # identical planner inputs yield identical plans — compile and validate
    # each distinct (jobs, seed) once per batch. The cached plan's full
    # planning cost is charged to every case sharing it (planning_time
    # reports what a standalone run of that case would spend).
    plan_cache: dict[tuple, tuple | None] = {}
    for i, sc in enumerate(scenarios):
        jobs = sc.make_jobs()
        key = (
            tuple((j.job_id, j.failed_node, j.requestor, j.helpers)
                  for j in jobs),
            seeds[i] if scheme == "random" else None,
        )
        hit = plan_cache.get(key, _MISSING)
        if hit is _MISSING:
            tic = _time.perf_counter()
            plan = plan_for_scheme(scheme, jobs, random_seed=seeds[i])
            clock = _time.perf_counter() - tic
            try:
                pa = compile_plan(plan)
            except UnsupportedPlanError:
                plan_cache[key] = None
                fallback.append(i)
                continue
            validate_plan_arrays(
                pa, max_recv_per_round=len(jobs[0].helpers)
                if scheme == "traditional" else 1,
            )
            hit = (plan, pa, clock)
            plan_cache[key] = hit
        elif hit is None:
            fallback.append(i)
            continue
        plan, pa, clock = hit
        prepared[i] = (jobs, plan, pa, clock)

    groups: dict[tuple, list[int]] = {}
    for i, (_, plan, _, _) in prepared.items():
        groups.setdefault((scenarios[i].num_nodes, plan.num_rounds),
                          []).append(i)
    for idxs in groups.values():
        sims = _run_rounds_batch(
            [scenarios[i] for i in idxs], scheme,
            [prepared[i][1] for i in idxs],
            [prepared[i][2] for i in idxs],
            [prepared[i][0] for i in idxs],
            [prepared[i][3] for i in idxs],
            bmf_optimize_all=bmf_optimize_all,
        )
        for i, r in zip(idxs, sims):
            results[i] = r
    for i in fallback:
        results[i] = run_scheme(
            scenarios[i], scheme,
            bmf_optimize_all=bmf_optimize_all, random_seed=seeds[i],
        )
    return results
