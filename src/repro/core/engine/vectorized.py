"""Batched vectorized round/pipeline engine over `PlanArrays`.

This is the array-native twin of `repro.core.simulator`: instead of one
Python event loop per scenario, a whole *batch* of scenarios advances
together through masked `(B, ...)` state arrays. Every case still takes
exactly the event steps it would take alone — each case has its own
`dt`, epoch boundary and completion mask — so per-case results match the
object engine (same float ops in the same order — bit-identical in
practice; the parity tests pin 1e-6 relative); only the bookkeeping
between events is vectorized:

* fan-in contention groups become a stable sort + segment reductions
  (`np.maximum.reduceat`) instead of per-receiver dict building, with
  Dirichlet share vectors (`IngressModel.share_weights`) memoized per
  (case, receiver, fan-in) across the whole batch instead of redrawn
  every event;
* PPT's recursive `supply_rate` becomes an iterative topological
  min-scan over edge-depth levels (`np.minimum.at` scatters);
* epoch flips refresh a per-case `(B, N, N)` bandwidth stack only when a
  case actually crosses its epoch boundary.

Planning is array-native too (`repro.core.engine.planner_arrays`): each
case's schedule is lowered straight to `PlanArrays` (no object plan on
the hot path), and the per-round BMF re-optimization — the paper's
"monitor + replan every timestamp" logic — runs *inside* the stepper as
`optimize_round_batch`: one batched candidate-path enumeration over the
live `(B, N, N)` bandwidth stack reroutes the bottleneck transfer of
every case at once, splicing the relayed paths back into the compiled
plans in place. The `(B, ...)` layout is the seam a device stepper
plugs into: both execution *and* replanning are array math over static
shapes, and `repro.core.engine.jax_stepper` exploits exactly that —
`run_work_vectorized(backend="jax")` swaps the numpy event loops for
jit-compiled `lax.while_loop`/`scan` programs while this module keeps
owning the host-side orchestration (planning, the per-round BMF
monitor-and-replan step, result bookkeeping). See `docs/engine.md` for
the backend matrix and fallback rules.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
import warnings

import numpy as np

from repro.core.engine.arrays import PlanArrays, decompile, splice_path
from repro.core.engine.planner_arrays import (lower_schedules_batch,
                                              msrepair_schedule_batch,
                                              optimize_round_batch,
                                              schedule_for_scheme)
from repro.core.ppt import build_ppt_tree
from repro.core.simulator import (Scenario, SimResult,
                                  pipeline_fill_latency, run_scheme)

_EPS = 1e-9
_GUARD = 100_000


# ------------------------------------------------------------ batch context
class _BatchBandwidth:
    """Per-case `(B, N, N)` bandwidth stack, refreshed on epoch crossings.

    `BandwidthTrace` cases (the bulk `sample_epochs` recordings from
    `TraceSuite.freeze`) index the recorded epoch stack directly;
    everything else goes through `matrix_at`, whose per-instance epoch
    memo is shared with the object engine and across a case's schemes.
    Either way a case's matrix is reloaded only when its own epoch
    boundary passes — between epochs the stack row is reused as-is.
    """

    _DENSE_LIMIT_BYTES = 128 * 1024 * 1024
    # build the dense all-trace gather stack only once this many crossings
    # per case have been served — batches that barely touch their traces
    # never pay the full (B, Emax, N, N) prefill copy, while churn-heavy
    # runs (the stress suites) amortize it almost immediately
    _DENSE_AFTER_CROSSINGS = 2

    def __init__(self, bwps, num_nodes: int):
        from repro.core.bandwidth import BandwidthTrace

        self.bwps = list(bwps)
        b = len(self.bwps)
        self.num_nodes = num_nodes
        self.stack = np.zeros((b, num_nodes, num_nodes), dtype=float)
        self.epoch = np.zeros(b, dtype=np.int64)
        self.epoch_end = np.full(b, -np.inf)
        # per-case prefetch block for live processes: (start_epoch, stack)
        self._live_block: list = [None] * b
        # per-case serving recipe: (interval, epochs, num_epochs, cycle)
        # for traces, None for everything served through matrix_at
        self._trace = [
            (bwp.change_interval, bwp.epochs, bwp.num_epochs, bwp.cycle)
            if type(bwp) is BandwidthTrace else None
            for bwp in self.bwps
        ]
        self._dense = None
        self._crossings = 0
        self._dense_ok = (
            b > 0 and all(tr is not None for tr in self._trace)
            and (b * max(tr[2] for tr in self._trace)
                 * num_nodes * num_nodes * 8) <= self._DENSE_LIMIT_BYTES
        )

    def _build_dense(self) -> None:
        """All-trace batches get a padded (B, Emax, N, N) stack so a whole
        refresh is one fancy gather instead of a per-case python loop."""
        b = len(self.bwps)
        emax = max(tr[2] for tr in self._trace)
        dense = np.zeros((b, emax, self.num_nodes, self.num_nodes))
        for i, (_, epochs, num_e, _) in enumerate(self._trace):
            n = epochs.shape[1]
            dense[i, :num_e, :n, :n] = epochs
        self._dense = dense
        self._interval = np.array([tr[0] for tr in self._trace])
        self._num_epochs = np.array([tr[2] for tr in self._trace])
        self._cycle = np.array([tr[3] for tr in self._trace])

    def refresh(self, t: np.ndarray, active: np.ndarray) -> None:
        """Reload matrices for active cases whose epoch boundary passed."""
        crossed = active & (t >= self.epoch_end)
        if self._dense is not None:
            rows = np.nonzero(crossed)[0]
            if rows.size:
                # floor of true division == BandwidthTrace.epoch_of
                # (floor(t / i), NOT t // i — float floordiv is fmod-based
                # and can differ by one epoch at exact-multiple boundaries)
                e = np.floor(t[rows] / self._interval[rows]).astype(np.int64)
                idx = np.where(self._cycle[rows], e % self._num_epochs[rows],
                               np.minimum(e, self._num_epochs[rows] - 1))
                self.stack[rows] = self._dense[rows, idx]
                self.epoch[rows] = e
                self.epoch_end[rows] = (e + 1) * self._interval[rows]
            return
        if self._dense_ok:
            self._crossings += int(crossed.sum())
            if self._crossings > self._DENSE_AFTER_CROSSINGS * len(self.bwps):
                self._build_dense()
                self.refresh(t, active)
                return
        for b in np.nonzero(crossed)[0]:
            tb = float(t[b])
            trace = self._trace[b]
            if trace is not None:
                interval, epochs, num_epochs, cycle = trace
                e = math.floor(tb / interval)   # == epoch_of(tb)
                self.epoch[b] = e
                self.epoch_end[b] = (e + 1) * interval
                self.stack[b] = epochs[e % num_epochs if cycle
                                       else min(e, num_epochs - 1)]
            else:
                bwp = self.bwps[b]
                interval = bwp.change_interval
                if interval is None:
                    self.epoch[b] = 0
                    self.epoch_end[b] = np.inf
                    self.stack[b] = bwp.matrix_at(tb)
                    continue
                e = bwp.epoch_of(tb)
                self.epoch[b] = e
                self.epoch_end[b] = (e + 1) * interval
                # serve from the process's aligned epoch block (one
                # vectorized `sample_epochs` per block, memoized on the
                # process instance — bit-identical to `matrix_at`, minus
                # the per-epoch wrapper overhead, shared across schemes)
                blk = self._live_block[b]
                if blk is None or not blk[0] <= e < blk[0] + blk[1].shape[0]:
                    blk = bwp.epochs_block(e)
                    self._live_block[b] = blk
                self.stack[b] = blk[1][e - blk[0]]


def _group_structure(
    b_idx: np.ndarray,
    recv: np.ndarray,
    epoch: np.ndarray,
    num_nodes: int,
    ingresses,
    degrade: np.ndarray,
    floor: np.ndarray,
    wcache: dict,
):
    """Precompute the fan-in grouping of concurrent (case, link) pairs.

    Returns None when every receiver has a single sender (m = 1
    degenerates to the standalone rate), else the sort order, segment
    starts, per-pair Dirichlet shares and per-group degradation factors.
    Reusable across event steps for as long as the *set* of concurrent
    pairs is unchanged (rates then vary only through the bandwidth
    matrices) and shares are persistent.
    """
    n = b_idx.size
    key = b_idx * num_nodes + recv
    order = np.argsort(key, kind="stable")
    skey = key[order]
    boundary = np.empty(n, dtype=bool)
    boundary[0] = True
    np.not_equal(skey[1:], skey[:-1], out=boundary[1:])
    starts = np.nonzero(boundary)[0]
    if starts.size == n:
        return None
    counts = np.diff(np.append(starts, n))
    gkey = skey[starts]
    gb = gkey // num_nodes
    factor = np.maximum(floor[gb], 1.0 - degrade[gb] * (counts - 1))

    w = np.ones(n)
    reusable = True
    for gi in np.nonzero(counts > 1)[0]:
        b, m = int(gb[gi]), int(counts[gi])
        v = int(gkey[gi]) % num_nodes
        ing = ingresses[b]
        if ing.persistent_shares:
            ck = (b, v, m)
        else:
            ck = (b, v, m, int(epoch[b]))
            reusable = False     # shares re-drawn per epoch: don't reuse
        ww = wcache.get(ck)
        if ww is None:
            ww = ing.share_weights(m, v, int(epoch[b]))
            wcache[ck] = ww
        w[starts[gi]: starts[gi] + m] = ww
    return order, starts, counts, factor, w, reusable


def _contended_rates_grouped(structure, standalone: np.ndarray) -> np.ndarray:
    """Apply a precomputed fan-in grouping to current standalone rates.

    Same arithmetic as `IngressModel.effective_rates` per group:
    cap = max(group) * factor(m), eff = min(standalone, share * cap).
    """
    if structure is None:
        return standalone
    order, starts, counts, factor, w, _ = structure
    sval = standalone[order]
    cap = np.maximum.reduceat(sval, starts) * factor
    eff = np.empty(sval.size)
    eff[order] = np.minimum(sval, w * np.repeat(cap, counts))
    return eff


# ------------------------------------------------------------- round engine
def execute_round_batch(
    hop_u: np.ndarray,           # (B, T, H) int, -1 padded
    hop_v: np.ndarray,           # (B, T, H) int
    n_hops: np.ndarray,          # (B, T) int — 0 marks padding transfers
    t0: np.ndarray,              # (B,) float
    bb: _BatchBandwidth,
    ingresses,
    chunk_mb: np.ndarray,        # (B,) float
    wcache: dict,
    degrade: np.ndarray,
    floor: np.ndarray,
) -> np.ndarray:
    """Advance every case until all its round transfers complete.

    The masked-array twin of `simulator.execute_round`: one iteration =
    one event (hop completion or epoch flip) *per active case*, all cases
    stepping concurrently, each by its own `dt`.
    """
    B, T, _ = hop_u.shape
    num_nodes = bb.stack.shape[1]
    t = np.asarray(t0, dtype=float).copy()
    if T == 0:
        return t
    hop_i = np.zeros((B, T), dtype=np.int64)
    left = np.broadcast_to(chunk_mb[:, None], (B, T)).copy()
    chunk_col = chunk_mb[:, None]
    eps_chunk = _EPS * chunk_col
    done = (hop_i >= n_hops).all(axis=1)
    iters = 0
    rates = np.zeros((B, T))
    cand = np.empty((B, T))
    # the (case, transfer) -> current-hop structure only changes when a hop
    # completes; between completions (i.e. across pure epoch-flip events)
    # the fan-in grouping and Dirichlet shares are reused as-is
    pairs_dirty = True
    act = bi = ti = u = v = structure = None

    while not done.all():
        iters += 1
        if iters > _GUARD:
            raise RuntimeError("simulator failed to converge")
        bb.refresh(t, ~done)
        if pairs_dirty:
            act = (hop_i < n_hops) & ~done[:, None]
            bi, ti = np.nonzero(act)         # row-major: per-case transfer order
            h = hop_i[bi, ti]
            u = hop_u[bi, ti, h]
            v = hop_v[bi, ti, h]
            structure = _group_structure(
                bi, v, bb.epoch, num_nodes, ingresses, degrade, floor, wcache)
            # non-persistent shares are epoch-keyed: rebuild every event
            pairs_dirty = structure is not None and not structure[5]
        eff = _contended_rates_grouped(structure, bb.stack[bi, u, v])
        rates.fill(0.0)
        rates[bi, ti] = np.maximum(eff, 0.0)

        cand.fill(np.inf)
        np.divide(left, rates, out=cand, where=act & (rates > 0))
        dt = np.minimum(bb.epoch_end - t, cand.min(axis=1))
        dt[~np.isfinite(dt) | (dt <= 0)] = _EPS
        dt[done] = 0.0

        rates *= dt[:, None]
        np.subtract(left, rates, out=left, where=act)
        t += dt
        compl = act & (left <= eps_chunk)
        if compl.any():
            hop_i += compl
            np.copyto(left, chunk_col, where=compl)
            done = (hop_i >= n_hops).all(axis=1)
            pairs_dirty = True
    return t


def _gather_all_rounds(
    arrays: list[PlanArrays],
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad every plan's rounds into (B, R, T, H) hop tensors, one pass.

    A plan with fewer than R rounds contributes all-padding rows for the
    missing rounds — batches mix round counts, and cases whose plan is
    exhausted just sit out the remaining rounds (their transfers are
    masked everywhere). Padding hops index node 0 so fancy-indexing
    stays in bounds; they are masked out by n_hops == 0 / hop_i >=
    n_hops before any rate math.
    """
    B = len(arrays)
    R = max(pa.num_rounds for pa in arrays)
    T = max((int(np.diff(pa.round_start).max(initial=0))
             for pa in arrays), default=0)
    H = max(pa.t_path.shape[1] - 1 for pa in arrays)
    hop_u = np.zeros((B, R, max(T, 1), max(H, 1)), dtype=np.int64)
    hop_v = np.zeros_like(hop_u)
    n_hops = np.zeros((B, R, max(T, 1)), dtype=np.int64)
    for b, pa in enumerate(arrays):
        nt = pa.num_transfers
        if not nt:
            continue
        starts = pa.round_start
        counts = np.diff(starts)
        rid = np.repeat(np.arange(pa.num_rounds), counts)
        pos = np.arange(nt) - np.repeat(starts[:-1], counts)
        path = pa.t_path
        hw = path.shape[1] - 1
        hop_u[b, rid, pos, :hw] = path[:, :-1]
        hop_v[b, rid, pos, :hw] = path[:, 1:]
        n_hops[b, rid, pos] = pa.t_path_len - 1
    # lift the -1 path padding to node 0 in one pass over the batch
    np.maximum(hop_u, 0, out=hop_u)
    np.maximum(hop_v, 0, out=hop_v)
    return hop_u, hop_v, n_hops


# ---------------------------------------------------------- pipeline engine
@dataclasses.dataclass
class _PipelinePrep:
    tree: object
    t_start: float
    plan_clock: float


def execute_pipeline_batch(
    child: np.ndarray,           # (B, E) int — 0-padded, dead via left == 0
    parent: np.ndarray,          # (B, E) int
    depth: np.ndarray,           # (B, E) int — child-node depth, 0 on padding
    edge_valid: np.ndarray,      # (B, E) bool
    t0: np.ndarray,              # (B,) float
    bb: _BatchBandwidth,
    ingresses,
    chunk_mb: np.ndarray,        # (B,) float
    wcache: dict,
    degrade: np.ndarray,
    floor: np.ndarray,
    duplex: np.ndarray,          # (B,) float
) -> np.ndarray:
    """Masked-array twin of `simulator.execute_pipeline`'s event loop.

    The recursive `supply_rate` (slowest live edge in the subtree feeding
    each node) is an iterative topological min-scan: edges are processed
    by descending child depth, scattering each edge's effective rate into
    its parent's supply cell with `np.minimum.at`.
    """
    B, E = child.shape
    num_nodes = bb.stack.shape[1]
    t = np.asarray(t0, dtype=float).copy()
    left = np.where(edge_valid, chunk_mb[:, None], 0.0)
    live = left > _EPS * chunk_mb[:, None]
    iters = np.zeros(B, dtype=np.int64)
    dmax = int(depth.max()) if depth.size else 0
    # live-edge structure (fan-in groups, duplex factors) changes only
    # when an edge drains; reuse it across pure epoch-flip events
    edges_dirty = True
    bi = ei = c = p = structure = rx_dup = tx_dup = None

    while live.any():
        case_on = live.any(axis=1)
        iters[case_on] += 1
        if iters.max() > _GUARD:
            raise RuntimeError("pipeline simulation failed to converge")
        bb.refresh(t, case_on)

        if edges_dirty:
            bi, ei = np.nonzero(live)        # row-major: per-case edge order
            c = child[bi, ei]
            p = parent[bi, ei]
            # rx fan-in contention at each parent; tx groups are singletons
            structure = _group_structure(
                bi, p, bb.epoch, num_nodes, ingresses, degrade, floor, wcache)
            has_rx = np.zeros((B, num_nodes), dtype=bool)
            has_rx[bi, p] = True
            has_tx = np.zeros((B, num_nodes), dtype=bool)
            has_tx[bi, c] = True
            rx_dup = np.where(has_tx[bi, p], duplex[bi], 1.0)
            tx_dup = np.where(has_rx[bi, c], duplex[bi], 1.0)
            edges_dirty = structure is not None and not structure[5]
        s = bb.stack[bi, c, p]
        rx_alloc = _contended_rates_grouped(structure, s) * rx_dup
        tx_alloc = s * tx_dup
        raw = np.minimum(np.maximum(rx_alloc, 0.0), np.maximum(tx_alloc, 0.0))
        raw_full = np.zeros((B, E))
        raw_full[bi, ei] = raw

        # iterative topological min-scan, deepest edges first
        node_supply = np.full((B, num_nodes), np.inf)
        eff_edge = raw_full.copy()
        for d in range(dmax, 0, -1):
            sel = live & (depth == d)
            if not sel.any():
                continue
            sb, se = np.nonzero(sel)
            val = np.minimum(raw_full[sb, se],
                             node_supply[sb, child[sb, se]])
            eff_edge[sb, se] = val
            np.minimum.at(node_supply, (sb, parent[sb, se]), val)
        rates = np.where(live, eff_edge, 0.0)

        cand = np.full((B, E), np.inf)
        np.divide(left, rates, out=cand, where=live & (rates > 0))
        dt = np.minimum(bb.epoch_end - t, cand.min(axis=1))
        dt = np.where(~np.isfinite(dt) | (dt <= 0), _EPS, dt)
        dt = np.where(case_on, dt, 0.0)

        left = np.where(live, left - rates * dt[:, None], left)
        t = t + dt
        new_live = left > _EPS * chunk_mb[:, None]
        if not np.array_equal(new_live, live):
            edges_dirty = True
        live = new_live
    return t


# ----------------------------------------------------------- batched scheme
def _ingress_params(scenarios):
    degrade = np.array([sc.ingress.degrade for sc in scenarios], dtype=float)
    floor = np.array([sc.ingress.floor for sc in scenarios], dtype=float)
    duplex = np.array([sc.ingress.duplex for sc in scenarios], dtype=float)
    return degrade, floor, duplex


def _chunk_array(scenarios) -> np.ndarray:
    # chunk_mb may arrive as python ints (benchmark grids use [8, 16, 32]);
    # the batched state math must stay float64
    return np.array([sc.chunk_mb for sc in scenarios], dtype=float)


def _run_ppt_batch(scenarios: list[Scenario],
                   engine_factory=None) -> list[SimResult]:
    B = len(scenarios)
    num_nodes = max(sc.num_nodes for sc in scenarios)
    preps: list[_PipelinePrep] = []
    for sc in scenarios:
        tic = _time.perf_counter()
        tree = build_ppt_tree(sc.make_jobs()[0], sc.bw.matrix_at(0.0))
        plan_clock = _time.perf_counter() - tic
        t_start = pipeline_fill_latency(tree, sc.bw.matrix_at(0.0),
                                        sc.chunk_mb)
        preps.append(_PipelinePrep(tree=tree, t_start=t_start,
                                   plan_clock=plan_clock))

    E = max(len(p.tree.parent) for p in preps)
    child = np.zeros((B, E), dtype=np.int64)
    parent = np.zeros((B, E), dtype=np.int64)
    depth_arr = np.zeros((B, E), dtype=np.int64)
    edge_valid = np.zeros((B, E), dtype=bool)
    for b, p in enumerate(preps):
        depths = p.tree.depths()
        for e, (c, par) in enumerate(p.tree.parent.items()):
            child[b, e] = c
            parent[b, e] = par
            depth_arr[b, e] = depths[c]
            edge_valid[b, e] = True

    t0 = np.array([p.t_start for p in preps])
    t_end = None
    if engine_factory is not None:
        from repro.core.engine.jax_stepper import EpochHorizonError

        engine = engine_factory(scenarios, num_nodes, parent, edge_valid)
        while engine is not None:       # grow the epoch horizon on overrun
            try:
                t_end = engine.execute(child, parent, depth_arr, edge_valid,
                                       t0)
                break
            except EpochHorizonError:
                engine = engine.grow()  # None once capped -> numpy fallback
    if t_end is None:
        bb = _BatchBandwidth([sc.bw for sc in scenarios], num_nodes)
        degrade, floor, duplex = _ingress_params(scenarios)
        chunk = _chunk_array(scenarios)
        t_end = execute_pipeline_batch(
            child, parent, depth_arr, edge_valid, t0, bb,
            [sc.ingress for sc in scenarios], chunk, {}, degrade, floor,
            duplex,
        )
    return [
        SimResult(
            scheme="ppt", total_time=float(t_end[b]),
            round_times=[float(t_end[b])], planning_time=preps[b].plan_clock,
            plan=None, log=[f"ppt tree edges={preps[b].tree.edges}"],
        )
        for b in range(B)
    ]


def _run_rounds_batch(
    scenarios: list[Scenario],
    schemes: list[str],
    arrays: list[PlanArrays],
    plan_clocks: list[float],
    *,
    bmf_rows: np.ndarray,          # (B,) bool — rows with per-round replan
    static_plan_time: bool,
    bmf_optimize_all: bool,
    keep_plans: bool,
    engine_factory=None,
) -> list[SimResult]:
    """Retry wrapper around `_run_rounds_once`: a device engine whose
    pre-sampled epoch horizon overflows gets its horizon grown and the
    attempt re-runs from scratch — any BMF splices the aborted attempt
    wrote into the compiled plans are rolled back first, so the retry
    replans from the same pristine state (results are identical; only
    the wasted attempt's wall-clock differs). `engine.grow()` returns
    None once capped, which drops the batch to the numpy steppers."""
    num_nodes = max(max(sc.num_nodes, pa.num_nodes)
                    for sc, pa in zip(scenarios, arrays))
    kw = dict(bmf_rows=bmf_rows, static_plan_time=static_plan_time,
              bmf_optimize_all=bmf_optimize_all, keep_plans=keep_plans)
    if engine_factory is None:
        return _run_rounds_once(scenarios, schemes, arrays, plan_clocks,
                                num_nodes, None, **kw)
    from repro.core.engine.jax_stepper import EpochHorizonError

    engine = engine_factory(scenarios, num_nodes, arrays)
    # rollback copies are only reachable through an engine's horizon
    # overflow — don't pay for them when the factory declined the batch
    snap = ([(pa.t_path.copy(), pa.t_path_len.copy(), pa.num_nodes)
             for pa in arrays]
            if engine is not None and bmf_rows.any() else None)
    while True:
        try:
            return _run_rounds_once(scenarios, schemes, arrays, plan_clocks,
                                    num_nodes, engine, **kw)
        except EpochHorizonError:
            if snap is not None:
                for pa, (tp, tl, nn) in zip(arrays, snap):
                    pa.t_path = tp.copy()
                    pa.t_path_len = tl.copy()
                    pa.num_nodes = nn
            engine = engine.grow()


def _run_rounds_once(
    scenarios: list[Scenario],
    schemes: list[str],
    arrays: list[PlanArrays],
    plan_clocks: list[float],
    num_nodes: int,
    engine,                        # device round engine, or None for numpy
    *,
    bmf_rows: np.ndarray,
    static_plan_time: bool,
    bmf_optimize_all: bool,
    keep_plans: bool,
) -> list[SimResult]:
    B = len(scenarios)
    rounds_of = [pa.num_rounds for pa in arrays]

    t = np.zeros(B)
    relay_hops = np.zeros(B, dtype=np.int64)
    logs: list[list[str]] = [[] for _ in range(B)]
    plan_clock = np.array(plan_clocks)
    hop_all_u, hop_all_v, n_hops_all = _gather_all_rounds(arrays)
    R = hop_all_u.shape[1]
    rt = np.zeros((R, B))
    brows = np.nonzero(bmf_rows)[0]

    if engine is not None and not brows.size:
        # no per-round replanning: the whole plan runs as one device
        # scan over the round axis instead of R host round-trips (and
        # none of the numpy batch prep below is needed)
        rt_all, t = engine.execute_rounds(hop_all_u, hop_all_v,
                                          n_hops_all, t)
        rt[:] = rt_all
        return _round_results(scenarios, schemes, arrays, rounds_of, t, rt,
                              plan_clock, relay_hops, logs, keep_plans)

    bb = _BatchBandwidth([sc.bw for sc in scenarios], num_nodes)
    degrade, floor, _ = _ingress_params(scenarios)
    ingresses = [sc.ingress for sc in scenarios]
    chunk = _chunk_array(scenarios)
    wcache: dict = {}

    bb_plan = bb
    idle_base = None
    if brows.size:
        # per-case idle pool: nodes outside every job's requestor/failed
        # set, limited to the case's own cluster (== simulator._idle_pool).
        # NOTE: built from the *scenario's* jobs, not the plan's — for
        # bmf/bmf_static the plan carries only the first job, but every
        # failed node must stay out of the relay pool.
        idle_base = np.zeros((brows.size, num_nodes), dtype=bool)
        for k, b in enumerate(brows):
            sc = scenarios[b]
            idle_base[k, : sc.num_nodes] = True
            for j in sc.make_jobs():
                idle_base[k, j.requestor] = False
                idle_base[k, j.failed_node] = False
        if static_plan_time:   # plan-once ablation: t=0 snapshot throughout
            bb_plan = _BatchBandwidth([sc.bw for sc in scenarios], num_nodes)
            bb_plan.refresh(np.zeros(B), np.ones(B, dtype=bool))

    for r in range(R):
        hop_u = hop_all_u[:, r]
        hop_v = hop_all_v[:, r]
        n_hops = n_hops_all[:, r]
        if brows.size:
            # in-stepper replan: one batched BMF pass reroutes every
            # replanning row's bottleneck transfers on the live stack
            tic = _time.perf_counter()
            if not static_plan_time:
                bb_plan.refresh(t, bmf_rows)
            hu, hv, nh = hop_u[brows], hop_v[brows], n_hops[brows]
            H = hu.shape[2]
            valid = np.arange(H)[None, None, :] < nh[:, :, None]
            vb, vt, vh = np.nonzero(valid)
            used = np.zeros((brows.size, num_nodes), dtype=bool)
            used[vb, hu[vb, vt, vh]] = True
            used[vb, hv[vb, vt, vh]] = True
            avail = idle_base & ~used
            hu, hv, stats, spliced = optimize_round_batch(
                hu, hv, nh, bb_plan.stack[brows], chunk[brows], avail,
                optimize_all=bmf_optimize_all,
            )
            if hu.shape[2] > H:     # a relayed path outgrew the hop axis
                pad = ((0, 0), (0, 0), (0, 0), (0, hu.shape[2] - H))
                hop_all_u = np.pad(hop_all_u, pad)
                hop_all_v = np.pad(hop_all_v, pad)
                hop_u, hop_v = hop_all_u[:, r], hop_all_v[:, r]
            hop_u[brows] = hu
            hop_v[brows] = hv
            n_hops[brows] = nh
            # batched planning wall-clock is shared: charge each replan
            # row its share (keeps sweep-level planning totals honest)
            plan_clock[brows] += (_time.perf_counter() - tic) / brows.size
            relay_hops[brows] += np.where(nh > 0, nh - 1, 0).sum(axis=1)
            for k in np.nonzero(stats.improved_links)[0]:
                b = brows[k]
                logs[b].append(
                    f"t={float(t[b]):.2f}s round {r}: BMF rerouted "
                    f"{int(stats.improved_links[k])} link(s), "
                    f"est -{float(stats.time_saved[k]):.2f}s"
                )
            for k, row, path in spliced:
                pa = arrays[brows[k]]
                splice_path(pa, int(pa.round_start[r]) + row, path)
        if engine is not None:
            t_end = engine.execute_round(hop_u, hop_v, n_hops, t)
        else:
            t_end = execute_round_batch(
                hop_u, hop_v, n_hops, t, bb, ingresses, chunk,
                wcache, degrade, floor,
            )
        rt[r] = t_end - t
        t = t_end

    return _round_results(scenarios, schemes, arrays, rounds_of, t, rt,
                          plan_clock, relay_hops, logs, keep_plans)


def _round_results(scenarios, schemes, arrays, rounds_of, t, rt, plan_clock,
                   relay_hops, logs, keep_plans) -> list[SimResult]:
    return [
        SimResult(
            scheme=schemes[b], total_time=float(t[b]),
            round_times=rt[: rounds_of[b], b].tolist(),
            planning_time=float(plan_clock[b]),
            plan=decompile(arrays[b]) if keep_plans else None,
            relay_hops=int(relay_hops[b]), log=logs[b],
        )
        for b in range(len(scenarios))
    ]


_BMF_SCHEMES = ("bmf", "msrepair", "bmf_static")


def run_work_vectorized(
    work: list[tuple[Scenario, str, int]],
    *,
    bmf_optimize_all: bool = False,
    keep_plans: bool = True,
    backend: str = "numpy",
) -> list[SimResult]:
    """Run `(scenario, scheme, seed)` work rows through the batched engine.

    This is the sweep engine's entry point: rows from *different schemes*
    share execution batches. Every row is planned straight into
    `PlanArrays` by the array-native planner layer — MSRepair rows
    through the lockstep batch scheduler, everything else per row — and
    all rows are lowered + validated in one array pass (no object plans,
    no compile step, no planner-input dedup). Rows then group by
    (cluster size, BMF replan mode): within a batch the steppers mask
    per-case round counts (a case whose plan is exhausted sits out the
    remaining rounds) and the per-round BMF re-optimization runs inside
    the stepper. PPT rows take the pipeline engine; a row whose plan
    cannot be lowered (term ids >= 64) falls back to the object engine.
    Results come back in input order and match `run_scheme` row for row
    (modulo wall-clock `planning_time`). `keep_plans=False` skips
    decompiling executed plans back to objects — the sweep default,
    since it strips plans anyway.

    `backend` picks the *execution* stepper: "numpy" (this module's
    masked-array loops) or "jax" (`repro.core.engine.jax_stepper`'s
    jit-compiled device programs — planning and the BMF replan host loop
    are unchanged). Batches the jax engine cannot take (jax missing,
    non-persistent ingress shares, epoch stacks past the memory cap)
    fall back to the numpy steppers; results are backend-independent
    either way.
    """
    round_factory = ppt_factory = None
    if backend == "jax":
        from repro.core.engine import jax_stepper

        if jax_stepper.jax_available():
            round_factory = jax_stepper.make_round_engine
            ppt_factory = jax_stepper.make_pipeline_engine
        else:
            warnings.warn(
                "backend='jax': jax is not importable; running the batch "
                "on the numpy vectorized engine instead",
                RuntimeWarning, stacklevel=2)
    elif backend != "numpy":
        raise ValueError(f"unknown backend {backend!r}")

    results: list[SimResult | None] = [None] * len(work)

    ppt_groups: dict[int, list[int]] = {}
    for i, (sc, scheme, _) in enumerate(work):
        if scheme == "ppt":
            ppt_groups.setdefault(sc.num_nodes, []).append(i)
    for idxs in ppt_groups.values():
        for i, r in zip(idxs, _run_ppt_batch([work[i][0] for i in idxs],
                                             engine_factory=ppt_factory)):
            results[i] = r

    rows = [i for i, (_, scheme, _) in enumerate(work) if scheme != "ppt"]
    items: dict[int, tuple] = {}
    clocks: dict[int, float] = {}
    recv_lims: dict[int, int] = {}
    ms_rows = [i for i in rows if work[i][1] == "msrepair"]
    if ms_rows:
        # true batched planning: all MSRepair rows in one lockstep pass
        jobs_list = [work[i][0].make_jobs() for i in ms_rows]
        tic = _time.perf_counter()
        scheds = msrepair_schedule_batch(jobs_list)
        share = (_time.perf_counter() - tic) / len(ms_rows)
        for i, jobs, sched in zip(ms_rows, jobs_list, scheds):
            items[i] = (jobs, sched, {"scheme": "msrepair"})
            clocks[i] = share
            recv_lims[i] = 1
    for i in rows:
        if i in items:
            continue
        sc, scheme, seed = work[i]
        jobs = sc.make_jobs()
        recv_lims[i] = (len(jobs[0].helpers)
                        if scheme == "traditional" else 1)
        tic = _time.perf_counter()
        items[i] = schedule_for_scheme(scheme, jobs, random_seed=seed)
        clocks[i] = _time.perf_counter() - tic

    pas = lower_schedules_batch(
        [items[i] for i in rows],
        max_recv_per_round=[recv_lims[i] for i in rows])
    prepared = {i: pa for i, pa in zip(rows, pas) if pa is not None}
    fallback = [i for i, pa in zip(rows, pas) if pa is None]

    # planning was batched across schemes above; execution batches are per
    # (cluster size, scheme): a scheme's cases share event structure, while
    # mixing schemes with very different event counts (star fan-in vs tree
    # rounds) would make short rows pay for the longest row's lockstep
    groups: dict[tuple, list[int]] = {}
    for i in prepared:
        groups.setdefault((work[i][0].num_nodes, work[i][1]), []).append(i)
    for (_, scheme), idxs in groups.items():
        static = scheme == "bmf_static"
        sims = _run_rounds_batch(
            [work[i][0] for i in idxs],
            [work[i][1] for i in idxs],
            [prepared[i] for i in idxs],
            [clocks[i] for i in idxs],
            bmf_rows=np.array([work[i][1] in _BMF_SCHEMES for i in idxs]),
            static_plan_time=static,
            bmf_optimize_all=bmf_optimize_all,
            keep_plans=keep_plans,
            engine_factory=round_factory,
        )
        for i, r in zip(idxs, sims):
            results[i] = r
    for i in fallback:
        sc, scheme, seed = work[i]
        r = run_scheme(sc, scheme,
                       bmf_optimize_all=bmf_optimize_all, random_seed=seed)
        results[i] = r if keep_plans else dataclasses.replace(r, plan=None)
    return results


def run_scheme_vectorized(
    scenarios: list[Scenario],
    scheme: str,
    *,
    seeds: list[int] | None = None,
    bmf_optimize_all: bool = False,
    keep_plans: bool = True,
    backend: str = "numpy",
) -> list[SimResult]:
    """Batched `run_scheme` for one scheme: see `run_work_vectorized`."""
    seeds = list(seeds) if seeds is not None else [0] * len(scenarios)
    if len(seeds) != len(scenarios):
        raise ValueError("seeds must match scenarios")
    return run_work_vectorized(
        [(sc, scheme, seed) for sc, seed in zip(scenarios, seeds)],
        bmf_optimize_all=bmf_optimize_all, keep_plans=keep_plans,
        backend=backend,
    )
