"""Structure-of-arrays IR for repair plans.

`compile_plan` lowers the object IR (`RepairPlan` / `Round` / `Transfer`)
into `PlanArrays`: padded integer arrays (hop endpoints, round offsets,
job ids) plus uint64 *term bitmasks* — one bit per helper node id. The
lowering is lossless: `decompile` reconstructs the exact original plan
(`decompile(compile_plan(p)) == p` for every planner's output, including
BMF-relayed paths), so the array form can sit on the hot path while the
object form stays the human-readable reference.

`validate_plan_arrays` is the array fast path behind
`repro.core.plan.validate_plan`: role conflicts per round become
`np.bincount`s over node ids, and the fragment bookkeeping (which terms
are XOR-folded where) becomes bitwise ops on a `(jobs, nodes)` uint64
holdings table instead of dict-of-set mutation.

Term (helper) node ids must fit a 64-bit mask (id < 64) — path, relay
and requestor ids are plain integers and have no such limit;
`compile_plan` raises `UnsupportedPlanError` otherwise and callers fall
back to the object path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import Job, RepairPlan, Round, Transfer

_MAX_MASK_NODES = 64


class UnsupportedPlanError(ValueError):
    """The plan cannot be lowered to arrays (helper/term ids >= 64)."""


def _terms_mask(terms) -> int:
    mask = 0
    for t in terms:
        t = int(t)
        if not 0 <= t < _MAX_MASK_NODES:
            raise UnsupportedPlanError(
                f"term node id {t} does not fit a uint64 bitmask"
            )
        mask |= 1 << t
    return mask


def _mask_terms(mask: int) -> frozenset[int]:
    out = []
    m = int(mask)
    while m:
        b = m & -m
        out.append(b.bit_length() - 1)
        m ^= b
    return frozenset(out)


@dataclasses.dataclass
class PlanArrays:
    """Compiled `RepairPlan`: jobs, transfers and rounds as padded arrays.

    Transfers are stored round-major (round r occupies rows
    `round_start[r]:round_start[r + 1]`, original in-round order
    preserved). Paths are padded with -1 to the longest path in the plan;
    `t_path_len` holds each row's true length. `t_job` carries the raw
    `Transfer.job` id for exact round-tripping, `t_job_idx` the position
    of that job in the `jobs` list (what the engine indexes with).
    """

    # jobs (J rows, original order)
    job_id: np.ndarray          # (J,) int32 — raw Job.job_id
    job_failed: np.ndarray      # (J,) int32
    job_requestor: np.ndarray   # (J,) int32
    job_helpers: np.ndarray     # (J, Hmax) int32, -1 padded (order kept)
    job_helpers_len: np.ndarray  # (J,) int32
    job_terms: np.ndarray       # (J,) uint64 — full term bitmask

    # transfers (T rows, round-major)
    t_src: np.ndarray           # (T,) int32
    t_dst: np.ndarray           # (T,) int32
    t_job: np.ndarray           # (T,) int32 — raw job id
    t_job_idx: np.ndarray       # (T,) int32 — row into the job arrays
    t_terms: np.ndarray         # (T,) uint64 — payload term bitmask
    t_path: np.ndarray          # (T, Pmax) int32, -1 padded
    t_path_len: np.ndarray      # (T,) int32

    # rounds
    round_start: np.ndarray     # (R + 1,) int32 offsets into transfer rows

    num_nodes: int              # max node id referenced + 1
    meta: dict

    @property
    def num_jobs(self) -> int:
        return int(self.job_id.shape[0])

    @property
    def num_rounds(self) -> int:
        return int(self.round_start.shape[0]) - 1

    @property
    def num_transfers(self) -> int:
        return int(self.t_src.shape[0])

    def round_rows(self, r: int) -> slice:
        return slice(int(self.round_start[r]), int(self.round_start[r + 1]))

    def round_hops(self, r: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Hop endpoint arrays for round r: (hop_u, hop_v, n_hops).

        hop_u/hop_v are (n, Hmax) with hop h of transfer i being
        `hop_u[i, h] -> hop_v[i, h]`; rows are valid up to `n_hops[i]`.
        """
        sl = self.round_rows(r)
        path = self.t_path[sl]
        return path[:, :-1], path[:, 1:], self.t_path_len[sl] - 1


def _job_fields(jobs: list[Job]) -> dict:
    """The job-side `PlanArrays` fields shared by both constructors."""
    hmax = max(max((len(j.helpers) for j in jobs), default=0), 1)
    ids = np.array(
        [(j.job_id, j.failed_node, j.requestor, len(j.helpers))
         for j in jobs], dtype=np.int32).reshape(len(jobs), 4)
    job_helpers = np.array(
        [(*j.helpers, *(-1,) * (hmax - len(j.helpers))) for j in jobs],
        dtype=np.int32).reshape(len(jobs), hmax)
    return dict(
        job_id=ids[:, 0],
        job_failed=ids[:, 1],
        job_requestor=ids[:, 2],
        job_helpers=job_helpers,
        job_helpers_len=ids[:, 3],
        job_terms=np.array([_terms_mask(j.helpers) for j in jobs],
                           dtype=np.uint64),
    )


def compile_plan(plan: RepairPlan) -> PlanArrays:
    """Lower a `RepairPlan` to `PlanArrays` (exact, reversible)."""
    jobs = plan.jobs
    job_index = {j.job_id: i for i, j in enumerate(jobs)}

    transfers = [t for rnd in plan.rounds for t in rnd.transfers]
    counts = [len(rnd.transfers) for rnd in plan.rounds]
    pmax = max(max((len(t.path) for t in transfers), default=2), 2)
    t_job_idx = []
    for t in transfers:
        if t.job not in job_index:
            raise UnsupportedPlanError(f"transfer {t} references unknown job")
        t_job_idx.append(job_index[t.job])

    max_node = max(
        [0]
        + [x for j in jobs for x in (j.failed_node, j.requestor, *j.helpers)]
        + [x for t in transfers for x in t.path]
    )
    return PlanArrays(
        **_job_fields(jobs),
        t_src=np.array([t.src for t in transfers], dtype=np.int32),
        t_dst=np.array([t.dst for t in transfers], dtype=np.int32),
        t_job=np.array([t.job for t in transfers], dtype=np.int32),
        t_job_idx=np.array(t_job_idx, dtype=np.int32),
        t_terms=np.array([_terms_mask(t.terms) for t in transfers],
                         dtype=np.uint64),
        t_path=np.array(
            [list(t.path) + [-1] * (pmax - len(t.path)) for t in transfers],
            dtype=np.int32).reshape(len(transfers), pmax),
        t_path_len=np.array([len(t.path) for t in transfers],
                            dtype=np.int32),
        round_start=np.concatenate(
            [[0], np.cumsum(counts, dtype=np.int64)]).astype(np.int32),
        num_nodes=max_node + 1,
        meta=dict(plan.meta),
    )


def _schedule_max_node(jobs: list[Job], flat: list) -> int:
    """Highest node id a schedule references (jobs + transfer endpoints)."""
    return max(
        [0]
        + [x for j in jobs for x in (j.failed_node, j.requestor, *j.helpers)]
        + [x for tr in flat for x in tr[:2]]
    )


def _schedule_t_job_idx(jobs: list[Job], flat: list,
                        job_col: np.ndarray) -> np.ndarray:
    """Row-into-jobs index per transfer (identity fast path included)."""
    if all(j.job_id == i for i, j in enumerate(jobs)):
        return job_col                  # identity mapping, no lookup pass
    index = {j.job_id: i for i, j in enumerate(jobs)}
    return np.array([index[tr[2]] for tr in flat], dtype=np.int32)


def _round_starts(rounds: list[list]) -> np.ndarray:
    starts = [0]
    for rnd in rounds:
        starts.append(starts[-1] + len(rnd))
    return np.array(starts, dtype=np.int32)


def _case_plan_arrays(
    jobs: list[Job],
    rounds: list[list[tuple[int, int, int, int]]],
    flat: list,
    meta: dict,
    job_fields: dict,
    ints: np.ndarray,          # (T, 3) int32 — src, dst, job columns
    terms: np.ndarray,         # (T,) uint64
) -> PlanArrays:
    """Assemble one case's `PlanArrays` from pre-lowered column arrays —
    the single construction path shared by `plan_arrays_from_schedule`
    and the batched `planner_arrays.lower_schedules_batch` (which passes
    slices of its concatenated buffers)."""
    return PlanArrays(
        **job_fields,
        t_src=ints[:, 0],
        t_dst=ints[:, 1],
        t_job=ints[:, 2],
        t_job_idx=_schedule_t_job_idx(jobs, flat, ints[:, 2]),
        t_terms=terms,
        t_path=ints[:, :2].copy(),
        t_path_len=np.full(len(flat), 2, dtype=np.int32),
        round_start=_round_starts(rounds),
        num_nodes=_schedule_max_node(jobs, flat) + 1,
        meta=dict(meta),
    )


def plan_arrays_from_schedule(
    jobs: list[Job],
    rounds: list[list[tuple[int, int, int, int]]],
    meta: dict,
) -> PlanArrays:
    """Build `PlanArrays` straight from a tuple schedule — no object plan.

    `rounds[r]` holds `(src, dst, job_id, terms_mask)` tuples (direct
    transfers; BMF relays are spliced in later via `splice_path`). This is
    the array planners' native exit: `decompile` of the result equals the
    object facade's `RepairPlan` exactly, but the hot path never allocates
    `Transfer`/`Round` objects.
    """
    job_index = {j.job_id: i for i, j in enumerate(jobs)}
    flat = [tr for rnd in rounds for tr in rnd]
    for src, dst, job_id, mask in flat:
        if job_id not in job_index:
            raise UnsupportedPlanError(
                f"transfer {src}->{dst} references unknown job {job_id}")
        if mask >> _MAX_MASK_NODES:
            raise UnsupportedPlanError(
                "term node id >= 64 does not fit a uint64 bitmask")
    # one bulk lowering: masks checked < 2**64 above, src/dst/job ids are
    # small non-negative ints, so a single uint64 matrix carries all four
    # columns and the typed views are cheap slices of it
    tarr = np.array(flat, dtype=np.uint64).reshape(len(flat), 4)
    ints = tarr[:, :3].astype(np.int32)
    return _case_plan_arrays(jobs, rounds, flat, meta, _job_fields(jobs),
                             ints, tarr[:, 3])


def splice_path(pa: PlanArrays, row: int, path: tuple[int, ...]) -> None:
    """Splice a (relayed) path into transfer `row`, widening `t_path` as
    needed — the incremental mutation the in-stepper BMF replanner uses.

    Validates the splice locally: the path must keep the transfer's
    endpoints, be acyclic and have length >= 2 (the `Transfer` invariants).
    Cross-transfer invariants (relay role exclusivity etc.) are *not*
    re-checked here — run `validate_plan_arrays` on the mutated plan for
    the full audit.
    """
    path = tuple(int(x) for x in path)
    if len(path) < 2:
        raise ValueError(f"path {path} too short")
    if path[0] != int(pa.t_src[row]) or path[-1] != int(pa.t_dst[row]):
        raise ValueError(
            f"path {path} does not keep endpoints "
            f"{int(pa.t_src[row])}->{int(pa.t_dst[row])}")
    if len(set(path)) != len(path):
        raise ValueError(f"cyclic path {path}")
    pmax = pa.t_path.shape[1]
    if len(path) > pmax:
        pa.t_path = np.concatenate(
            [pa.t_path,
             np.full((pa.t_path.shape[0], len(path) - pmax), -1,
                     dtype=np.int32)], axis=1)
    pa.t_path[row, : len(path)] = path
    pa.t_path[row, len(path):] = -1
    pa.t_path_len[row] = len(path)
    if max(path) >= pa.num_nodes:
        pa.num_nodes = max(path) + 1


def relabel_plan_nodes(pa: PlanArrays, perm: np.ndarray) -> PlanArrays:
    """A copy of `pa` with every node id mapped through `perm`.

    `perm[old] = new` must be defined for every id the plan references
    and injective over them; term/helper images must stay < 64 (the
    bitmask limit). This is how the byte-verification layer replays one
    logical plan against a *placed* stripe (`repro.ec.stripe`): the
    planner's block-position node ids are relabeled to the failure
    domains the stripe actually occupies, and the relabeled plan is as
    valid as the original (renaming preserves every role/fold invariant).
    """
    perm = np.asarray(perm, dtype=np.int64)
    used = np.concatenate([
        pa.job_failed, pa.job_requestor,
        pa.job_helpers[pa.job_helpers >= 0],
        pa.t_path[pa.t_path >= 0],
    ])
    if used.size and (used.max() >= perm.size or (perm[used] < 0).any()):
        raise ValueError("perm does not cover every node id in the plan")
    imgs = perm[np.unique(used)] if used.size else np.array([], dtype=np.int64)
    if np.unique(imgs).size != imgs.size:
        raise ValueError("perm is not injective over the plan's node ids")

    def _map(a: np.ndarray) -> np.ndarray:
        out = np.where(a >= 0, perm[np.maximum(a, 0)], a)
        return out.astype(a.dtype)

    def _map_masks(masks: np.ndarray) -> np.ndarray:
        out = np.zeros_like(masks)
        for i, m in enumerate(int(x) for x in masks):
            new = 0
            while m:
                b = m & -m
                t = perm[b.bit_length() - 1]
                if not 0 <= t < _MAX_MASK_NODES:
                    raise UnsupportedPlanError(
                        f"relabeled term id {t} does not fit a uint64 bitmask")
                new |= 1 << int(t)
                m ^= b
            out[i] = new
        return out

    return PlanArrays(
        job_id=pa.job_id.copy(),
        job_failed=_map(pa.job_failed),
        job_requestor=_map(pa.job_requestor),
        job_helpers=_map(pa.job_helpers),
        job_helpers_len=pa.job_helpers_len.copy(),
        job_terms=_map_masks(pa.job_terms),
        t_src=_map(pa.t_src),
        t_dst=_map(pa.t_dst),
        t_job=pa.t_job.copy(),
        t_job_idx=pa.t_job_idx.copy(),
        t_terms=_map_masks(pa.t_terms),
        t_path=_map(pa.t_path),
        t_path_len=pa.t_path_len.copy(),
        round_start=pa.round_start.copy(),
        num_nodes=int(perm[used].max()) + 1 if used.size else pa.num_nodes,
        meta=dict(pa.meta),
    )


def decompile(pa: PlanArrays) -> RepairPlan:
    """Reconstruct the exact `RepairPlan` that `compile_plan` lowered."""
    jobs = [
        Job(
            job_id=int(pa.job_id[i]),
            failed_node=int(pa.job_failed[i]),
            requestor=int(pa.job_requestor[i]),
            helpers=tuple(
                int(h) for h in pa.job_helpers[i, : int(pa.job_helpers_len[i])]
            ),
        )
        for i in range(pa.num_jobs)
    ]
    rounds = []
    for r in range(pa.num_rounds):
        sl = pa.round_rows(r)
        rounds.append(Round(transfers=[
            Transfer(
                src=int(pa.t_src[i]),
                dst=int(pa.t_dst[i]),
                job=int(pa.t_job[i]),
                terms=_mask_terms(pa.t_terms[i]),
                path=tuple(int(x) for x in
                           pa.t_path[i, : int(pa.t_path_len[i])]),
            )
            for i in range(sl.start, sl.stop)
        ]))
    return RepairPlan(jobs=jobs, rounds=rounds, meta=dict(pa.meta))


# below this many transfers the bincount machinery costs more numpy-call
# overhead than a plain python scan of the (tiny) id lists saves
_SMALL_VALIDATE_TRANSFERS = 64


def _validate_roles_small(pa: PlanArrays, max_recv_per_round: int,
                          srcs: list, dsts: list) -> None:
    """Per-round role-exclusivity scan for small plans (python counters
    over the id lists — same violations, same messages as the array
    path, reported round by round like the object walk)."""
    lens = pa.t_path_len.tolist()
    paths = pa.t_path.tolist()
    starts = pa.round_start.tolist()
    for r in range(pa.num_rounds):
        send: dict[int, int] = {}
        recv: dict[int, int] = {}
        relay: dict[int, int] = {}
        for i in range(starts[r], starts[r + 1]):
            send[srcs[i]] = send.get(srcs[i], 0) + 1
            recv[dsts[i]] = recv.get(dsts[i], 0) + 1
            for rl in paths[i][1: lens[i] - 1]:
                relay[rl] = relay.get(rl, 0) + 1
        for node, c in send.items():
            if c > 1:
                raise ValueError(
                    f"node {node} sends {c} transfers in one round")
            if relay.get(node):
                raise ValueError(f"node {node} both sends and relays")
            if recv.get(node):
                raise ValueError(
                    f"node {node} both sends and receives in a round")
        for node, c in recv.items():
            if c > max_recv_per_round:
                raise ValueError(
                    f"node {node} receives {c} transfers in one round")
            if relay.get(node):
                raise ValueError(f"node {node} both receives and relays")
        for node, c in relay.items():
            if c > 1:
                raise ValueError(
                    f"relay node {node} used {c} times in one round")


def validate_plan_arrays(pa: PlanArrays, *, max_recv_per_round: int = 1) -> None:
    """Array fast path of `repro.core.plan.validate_plan`.

    Enforces the same invariants (and raises `ValueError` for the same
    violations) as the object-based `FragmentState` walk. Role exclusivity
    is checked for *all rounds at once*: one `np.bincount` per role over
    `round * N + node` keys replaces per-round dict counters (small plans
    take a python scan instead — the bincount setup costs more than it
    saves there). Fragment movement stays a sequential walk, but over
    term *bitmasks* (python ints, no set allocation). When a plan holds
    several violations the first one reported may differ from the object
    path; the accept/reject verdict never does.
    """
    n = max(int(pa.num_nodes), 1)
    num_r = pa.num_rounds
    num_t = pa.num_transfers
    srcs = pa.t_src.tolist()
    dsts = pa.t_dst.tolist()
    if num_t and num_t < _SMALL_VALIDATE_TRANSFERS:
        _validate_roles_small(pa, max_recv_per_round, srcs, dsts)
    elif num_t:
        counts = np.diff(pa.round_start).astype(np.int64)
        round_id = np.repeat(np.arange(num_r, dtype=np.int64), counts)
        size = num_r * n
        send_c = np.bincount(round_id * n + pa.t_src, minlength=size)
        recv_c = np.bincount(round_id * n + pa.t_dst, minlength=size)
        cols = np.arange(pa.t_path.shape[1])
        relay_sel = ((cols[None, :] >= 1)
                     & (cols[None, :] < (pa.t_path_len - 1)[:, None]))
        relay_keys = (round_id[:, None] * n + pa.t_path)[relay_sel]
        relay_c = (np.bincount(relay_keys, minlength=size)
                   if relay_keys.size else np.zeros(size, dtype=np.int64))

        def _first(mask):
            k = int(np.nonzero(mask)[0][0])
            return k % n, k

        if (send_c > 1).any():
            node, k = _first(send_c > 1)
            raise ValueError(
                f"node {node} sends {int(send_c[k])} transfers in one round")
        if ((send_c > 0) & (relay_c > 0)).any():
            node, _ = _first((send_c > 0) & (relay_c > 0))
            raise ValueError(f"node {node} both sends and relays")
        if ((send_c > 0) & (recv_c > 0)).any():
            node, _ = _first((send_c > 0) & (recv_c > 0))
            raise ValueError(f"node {node} both sends and receives in a round")
        if (recv_c > max_recv_per_round).any():
            node, k = _first(recv_c > max_recv_per_round)
            raise ValueError(
                f"node {node} receives {int(recv_c[k])} transfers in one round")
        if ((recv_c > 0) & (relay_c > 0)).any():
            node, _ = _first((recv_c > 0) & (relay_c > 0))
            raise ValueError(f"node {node} both receives and relays")
        if (relay_c > 1).any():
            node, k = _first(relay_c > 1)
            raise ValueError(
                f"relay node {node} used {int(relay_c[k])} times in one round")

    # fragment movement, in transfer order (a source's holding must be
    # forwarded whole — XOR-folds cannot be split); python-int bit ops
    hold = [[0] * n for _ in range(pa.num_jobs)]
    helpers_flat = pa.job_helpers.tolist()
    hlens = pa.job_helpers_len.tolist()
    for j in range(pa.num_jobs):
        for h in helpers_flat[j][: hlens[j]]:
            hold[j][h] = 1 << h
    jidx = pa.t_job_idx.tolist()
    jraw = pa.t_job.tolist()
    terms = pa.t_terms.tolist()
    for i in range(num_t):
        j, s, d, sent = jidx[i], srcs[i], dsts[i], terms[i]
        row = hold[j]
        held = row[s]
        if held == 0 or held != sent:
            raise ValueError(
                f"transfer {s}->{d} (job {jraw[i]}) sends terms not matching "
                f"src holding (held={sorted(_mask_terms(held))}, "
                f"sent={sorted(_mask_terms(sent))})"
            )
        row[s] = 0
        if row[d] & sent:
            raise ValueError(
                f"duplicate terms arriving at node {d}: "
                f"{sorted(_mask_terms(row[d] & sent))}"
            )
        row[d] |= sent

    full = pa.job_terms.tolist()
    req = pa.job_requestor.tolist()
    for j in range(pa.num_jobs):
        if hold[j][req[j]] != full[j]:
            raise ValueError("plan does not complete all jobs")
