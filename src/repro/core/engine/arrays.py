"""Structure-of-arrays IR for repair plans.

`compile_plan` lowers the object IR (`RepairPlan` / `Round` / `Transfer`)
into `PlanArrays`: padded integer arrays (hop endpoints, round offsets,
job ids) plus uint64 *term bitmasks* — one bit per helper node id. The
lowering is lossless: `decompile` reconstructs the exact original plan
(`decompile(compile_plan(p)) == p` for every planner's output, including
BMF-relayed paths), so the array form can sit on the hot path while the
object form stays the human-readable reference.

`validate_plan_arrays` is the array fast path behind
`repro.core.plan.validate_plan`: role conflicts per round become
`np.bincount`s over node ids, and the fragment bookkeeping (which terms
are XOR-folded where) becomes bitwise ops on a `(jobs, nodes)` uint64
holdings table instead of dict-of-set mutation.

Term (helper) node ids must fit a 64-bit mask (id < 64) — path, relay
and requestor ids are plain integers and have no such limit;
`compile_plan` raises `UnsupportedPlanError` otherwise and callers fall
back to the object path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import Job, RepairPlan, Round, Transfer

_MAX_MASK_NODES = 64


class UnsupportedPlanError(ValueError):
    """The plan cannot be lowered to arrays (helper/term ids >= 64)."""


def _terms_mask(terms) -> int:
    mask = 0
    for t in terms:
        t = int(t)
        if not 0 <= t < _MAX_MASK_NODES:
            raise UnsupportedPlanError(
                f"term node id {t} does not fit a uint64 bitmask"
            )
        mask |= 1 << t
    return mask


def _mask_terms(mask: int) -> frozenset[int]:
    out = []
    m = int(mask)
    while m:
        b = m & -m
        out.append(b.bit_length() - 1)
        m ^= b
    return frozenset(out)


@dataclasses.dataclass
class PlanArrays:
    """Compiled `RepairPlan`: jobs, transfers and rounds as padded arrays.

    Transfers are stored round-major (round r occupies rows
    `round_start[r]:round_start[r + 1]`, original in-round order
    preserved). Paths are padded with -1 to the longest path in the plan;
    `t_path_len` holds each row's true length. `t_job` carries the raw
    `Transfer.job` id for exact round-tripping, `t_job_idx` the position
    of that job in the `jobs` list (what the engine indexes with).
    """

    # jobs (J rows, original order)
    job_id: np.ndarray          # (J,) int32 — raw Job.job_id
    job_failed: np.ndarray      # (J,) int32
    job_requestor: np.ndarray   # (J,) int32
    job_helpers: np.ndarray     # (J, Hmax) int32, -1 padded (order kept)
    job_helpers_len: np.ndarray  # (J,) int32
    job_terms: np.ndarray       # (J,) uint64 — full term bitmask

    # transfers (T rows, round-major)
    t_src: np.ndarray           # (T,) int32
    t_dst: np.ndarray           # (T,) int32
    t_job: np.ndarray           # (T,) int32 — raw job id
    t_job_idx: np.ndarray       # (T,) int32 — row into the job arrays
    t_terms: np.ndarray         # (T,) uint64 — payload term bitmask
    t_path: np.ndarray          # (T, Pmax) int32, -1 padded
    t_path_len: np.ndarray      # (T,) int32

    # rounds
    round_start: np.ndarray     # (R + 1,) int32 offsets into transfer rows

    num_nodes: int              # max node id referenced + 1
    meta: dict

    @property
    def num_jobs(self) -> int:
        return int(self.job_id.shape[0])

    @property
    def num_rounds(self) -> int:
        return int(self.round_start.shape[0]) - 1

    @property
    def num_transfers(self) -> int:
        return int(self.t_src.shape[0])

    def round_rows(self, r: int) -> slice:
        return slice(int(self.round_start[r]), int(self.round_start[r + 1]))

    def round_hops(self, r: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Hop endpoint arrays for round r: (hop_u, hop_v, n_hops).

        hop_u/hop_v are (n, Hmax) with hop h of transfer i being
        `hop_u[i, h] -> hop_v[i, h]`; rows are valid up to `n_hops[i]`.
        """
        sl = self.round_rows(r)
        path = self.t_path[sl]
        return path[:, :-1], path[:, 1:], self.t_path_len[sl] - 1


def compile_plan(plan: RepairPlan) -> PlanArrays:
    """Lower a `RepairPlan` to `PlanArrays` (exact, reversible)."""
    jobs = plan.jobs
    hmax = max(max((len(j.helpers) for j in jobs), default=0), 1)
    job_helpers = [list(j.helpers) + [-1] * (hmax - len(j.helpers))
                   for j in jobs]
    job_index = {j.job_id: i for i, j in enumerate(jobs)}

    transfers = [t for rnd in plan.rounds for t in rnd.transfers]
    counts = [len(rnd.transfers) for rnd in plan.rounds]
    pmax = max(max((len(t.path) for t in transfers), default=2), 2)
    t_job_idx = []
    for t in transfers:
        if t.job not in job_index:
            raise UnsupportedPlanError(f"transfer {t} references unknown job")
        t_job_idx.append(job_index[t.job])

    max_node = max(
        [0]
        + [x for j in jobs for x in (j.failed_node, j.requestor, *j.helpers)]
        + [x for t in transfers for x in t.path]
    )
    return PlanArrays(
        job_id=np.array([j.job_id for j in jobs], dtype=np.int32),
        job_failed=np.array([j.failed_node for j in jobs], dtype=np.int32),
        job_requestor=np.array([j.requestor for j in jobs], dtype=np.int32),
        job_helpers=np.array(job_helpers, dtype=np.int32).reshape(
            len(jobs), hmax),
        job_helpers_len=np.array([len(j.helpers) for j in jobs],
                                 dtype=np.int32),
        job_terms=np.array([_terms_mask(j.helpers) for j in jobs],
                           dtype=np.uint64),
        t_src=np.array([t.src for t in transfers], dtype=np.int32),
        t_dst=np.array([t.dst for t in transfers], dtype=np.int32),
        t_job=np.array([t.job for t in transfers], dtype=np.int32),
        t_job_idx=np.array(t_job_idx, dtype=np.int32),
        t_terms=np.array([_terms_mask(t.terms) for t in transfers],
                         dtype=np.uint64),
        t_path=np.array(
            [list(t.path) + [-1] * (pmax - len(t.path)) for t in transfers],
            dtype=np.int32).reshape(len(transfers), pmax),
        t_path_len=np.array([len(t.path) for t in transfers],
                            dtype=np.int32),
        round_start=np.concatenate(
            [[0], np.cumsum(counts, dtype=np.int64)]).astype(np.int32),
        num_nodes=max_node + 1,
        meta=dict(plan.meta),
    )


def decompile(pa: PlanArrays) -> RepairPlan:
    """Reconstruct the exact `RepairPlan` that `compile_plan` lowered."""
    jobs = [
        Job(
            job_id=int(pa.job_id[i]),
            failed_node=int(pa.job_failed[i]),
            requestor=int(pa.job_requestor[i]),
            helpers=tuple(
                int(h) for h in pa.job_helpers[i, : int(pa.job_helpers_len[i])]
            ),
        )
        for i in range(pa.num_jobs)
    ]
    rounds = []
    for r in range(pa.num_rounds):
        sl = pa.round_rows(r)
        rounds.append(Round(transfers=[
            Transfer(
                src=int(pa.t_src[i]),
                dst=int(pa.t_dst[i]),
                job=int(pa.t_job[i]),
                terms=_mask_terms(pa.t_terms[i]),
                path=tuple(int(x) for x in
                           pa.t_path[i, : int(pa.t_path_len[i])]),
            )
            for i in range(sl.start, sl.stop)
        ]))
    return RepairPlan(jobs=jobs, rounds=rounds, meta=dict(pa.meta))


def validate_plan_arrays(pa: PlanArrays, *, max_recv_per_round: int = 1) -> None:
    """Array fast path of `repro.core.plan.validate_plan`.

    Enforces the same invariants (and raises `ValueError` for the same
    violations) as the object-based `FragmentState` walk. Role exclusivity
    is checked for *all rounds at once*: one `np.bincount` per role over
    `round * N + node` keys replaces per-round dict counters. Fragment
    movement stays a sequential walk, but over term *bitmasks* (python
    ints, no set allocation). When a plan holds several violations the
    first one reported may differ from the object path; the accept/reject
    verdict never does.
    """
    n = max(int(pa.num_nodes), 1)
    num_r = pa.num_rounds
    num_t = pa.num_transfers
    if num_t:
        counts = np.diff(pa.round_start).astype(np.int64)
        round_id = np.repeat(np.arange(num_r, dtype=np.int64), counts)
        size = num_r * n
        send_c = np.bincount(round_id * n + pa.t_src, minlength=size)
        recv_c = np.bincount(round_id * n + pa.t_dst, minlength=size)
        cols = np.arange(pa.t_path.shape[1])
        relay_sel = ((cols[None, :] >= 1)
                     & (cols[None, :] < (pa.t_path_len - 1)[:, None]))
        relay_keys = (round_id[:, None] * n + pa.t_path)[relay_sel]
        relay_c = (np.bincount(relay_keys, minlength=size)
                   if relay_keys.size else np.zeros(size, dtype=np.int64))

        def _first(mask):
            k = int(np.nonzero(mask)[0][0])
            return k % n, k

        if (send_c > 1).any():
            node, k = _first(send_c > 1)
            raise ValueError(
                f"node {node} sends {int(send_c[k])} transfers in one round")
        if ((send_c > 0) & (relay_c > 0)).any():
            node, _ = _first((send_c > 0) & (relay_c > 0))
            raise ValueError(f"node {node} both sends and relays")
        if ((send_c > 0) & (recv_c > 0)).any():
            node, _ = _first((send_c > 0) & (recv_c > 0))
            raise ValueError(f"node {node} both sends and receives in a round")
        if (recv_c > max_recv_per_round).any():
            node, k = _first(recv_c > max_recv_per_round)
            raise ValueError(
                f"node {node} receives {int(recv_c[k])} transfers in one round")
        if ((recv_c > 0) & (relay_c > 0)).any():
            node, _ = _first((recv_c > 0) & (relay_c > 0))
            raise ValueError(f"node {node} both receives and relays")
        if (relay_c > 1).any():
            node, k = _first(relay_c > 1)
            raise ValueError(
                f"relay node {node} used {int(relay_c[k])} times in one round")

    # fragment movement, in transfer order (a source's holding must be
    # forwarded whole — XOR-folds cannot be split); python-int bit ops
    hold = [[0] * n for _ in range(pa.num_jobs)]
    helpers_flat = pa.job_helpers.tolist()
    for j in range(pa.num_jobs):
        for h in helpers_flat[j][: int(pa.job_helpers_len[j])]:
            hold[j][h] = 1 << h
    srcs = pa.t_src.tolist()
    dsts = pa.t_dst.tolist()
    jidx = pa.t_job_idx.tolist()
    jraw = pa.t_job.tolist()
    terms = pa.t_terms.tolist()
    for i in range(num_t):
        j, s, d, sent = jidx[i], srcs[i], dsts[i], terms[i]
        row = hold[j]
        held = row[s]
        if held == 0 or held != sent:
            raise ValueError(
                f"transfer {s}->{d} (job {jraw[i]}) sends terms not matching "
                f"src holding (held={sorted(_mask_terms(held))}, "
                f"sent={sorted(_mask_terms(sent))})"
            )
        row[s] = 0
        if row[d] & sent:
            raise ValueError(
                f"duplicate terms arriving at node {d}: "
                f"{sorted(_mask_terms(row[d] & sent))}"
            )
        row[d] |= sent

    full = pa.job_terms.tolist()
    req = pa.job_requestor.tolist()
    for j in range(pa.num_jobs):
        if hold[j][req[j]] != full[j]:
            raise ValueError("plan does not complete all jobs")
