"""Array-native repair engine: compiled plan arrays + batched steppers.

The compile/execute split mirrors a small compiler stack:

* `repro.core.engine.arrays` — `compile_plan` lowers the object plan IR
  to `PlanArrays` (padded integer arrays + uint64 term bitmasks),
  `decompile` round-trips exactly, `validate_plan_arrays` is the array
  fast path behind `repro.core.plan.validate_plan`;
* `repro.core.engine.vectorized` — masked-array event steppers that
  advance a whole `(B, ...)` batch of scenarios at once, plus
  `run_scheme_vectorized`, the batched twin of `simulator.run_scheme`
  that `repro.sim.sweep.run_sweep(executor="vectorized")` dispatches to.

The object-based engine in `repro.core.simulator` stays the reference
implementation; parity tests pin the vectorized path to it.
"""
from repro.core.engine.arrays import (PlanArrays, UnsupportedPlanError,
                                      compile_plan, decompile,
                                      validate_plan_arrays)
from repro.core.engine.vectorized import (execute_pipeline_batch,
                                          execute_round_batch,
                                          run_scheme_vectorized)

__all__ = [
    "PlanArrays",
    "UnsupportedPlanError",
    "compile_plan",
    "decompile",
    "validate_plan_arrays",
    "execute_pipeline_batch",
    "execute_round_batch",
    "run_scheme_vectorized",
]
