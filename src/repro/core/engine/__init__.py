"""Array-native repair engine: compiled plans, planners + batched steppers.

The compile/plan/execute split mirrors a small compiler stack:

* `repro.core.engine.arrays` — `compile_plan` lowers the object plan IR
  to `PlanArrays` (padded integer arrays + uint64 term bitmasks),
  `plan_arrays_from_schedule` builds them straight from tuple schedules,
  `splice_path` mutates a compiled plan in place (the BMF replan hook),
  `decompile` round-trips exactly, `validate_plan_arrays` is the array
  fast path behind `repro.core.plan.validate_plan`;
* `repro.core.engine.planner_arrays` — the array-native planner layer:
  batched BMF path search / round optimization over `(B, N, N)`
  bandwidth stacks, and the tuple schedulers the object planners in
  `repro.core.{msrepair,bmf,ppt}` facade over;
* `repro.core.engine.vectorized` — masked-array event steppers that
  advance a whole `(B, ...)` batch of scenarios at once, plus
  `run_scheme_vectorized`, the batched twin of `simulator.run_scheme`
  that `repro.sim.sweep.run_sweep(executor="vectorized")` dispatches to;
* `repro.core.engine.jax_stepper` — the same steppers as jit-compiled
  JAX device programs (`lax.while_loop`/`scan` over static padded
  shapes) behind `run_sweep(executor="jax")`; planning and replanning
  stay on the host, execution runs on the accelerator;
* `repro.core.engine.dataplane` — the byte data plane: batches of
  compiled plans executed over *real bytes* (`(B, slots, nbytes)`
  buffer tensors, batched GF(256) premultiply + segment-XOR through
  `repro.kernels.ops`), byte-identical to the serial oracle in
  `repro.core.executor`.

The object-based engine in `repro.core.simulator` stays the reference
implementation; parity tests pin the vectorized path to it.

`vectorized` is loaded lazily (PEP 562): it imports the simulator, whose
planner facades import `planner_arrays` from this package — eager loading
would cycle.
"""
from repro.core.engine.arrays import (PlanArrays, UnsupportedPlanError,
                                      compile_plan, decompile,
                                      plan_arrays_from_schedule,
                                      relabel_plan_nodes, splice_path,
                                      validate_plan_arrays)

__all__ = [
    "PlanArrays",
    "UnsupportedPlanError",
    "compile_plan",
    "decompile",
    "plan_arrays_from_schedule",
    "splice_path",
    "validate_plan_arrays",
    "execute_pipeline_batch",
    "execute_round_batch",
    "run_scheme_vectorized",
    "jax_available",
    "BatchExecutionResult",
    "execute_plans_batch",
    "identity_block_map",
    "relabel_plan_nodes",
]

_VECTORIZED = ("execute_pipeline_batch", "execute_round_batch",
               "run_scheme_vectorized")
_JAX = ("jax_available",)
# the byte data plane imports jax via repro.kernels — lazy like the
# jax stepper, so numpy-only sweep workers stay cheap to spawn
_DATAPLANE = ("BatchExecutionResult", "execute_plans_batch",
              "identity_block_map")


def __getattr__(name):
    if name in _VECTORIZED:
        from repro.core.engine import vectorized

        return getattr(vectorized, name)
    if name in _JAX:
        from repro.core.engine import jax_stepper

        return getattr(jax_stepper, name)
    if name in _DATAPLANE:
        from repro.core.engine import dataplane

        return getattr(dataplane, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
