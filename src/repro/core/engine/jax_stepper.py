"""JAX-native sweep executor: jit/`lax.while_loop` steppers behind the
`(B, ...)` seam.

`repro.core.engine.vectorized` documents its batch layout as "the seam a
future `jax.vmap`/Pallas stepper plugs into"; this module is that
stepper. The host-side orchestration (planning, BMF monitor-and-replan,
result bookkeeping) stays in `vectorized.py` — this module replaces only
the *event loops* with jit-compiled device programs:

* `JaxRoundEngine.execute_round` — the masked round stepper
  (`execute_round_batch`'s twin) as one `lax.while_loop` over static
  padded `(B, T, H)` shapes: per-case dt / epoch / completion masks,
  fan-in segment reductions re-expressed as dense `(B, T, N)`
  one-hot matches (cumsum positions, max-reductions), all in float64.
* `JaxRoundEngine.execute_rounds` — whole multi-round plans as one
  `lax.scan` over the round axis (used when no per-round replanning is
  required, i.e. everything except the BMF/MSRepair monitor loop —
  those route through `execute_round` between numpy replan steps).
  The per-round BMF monitor-and-replan itself stays on the *batched
  numpy* path (`optimize_round_batch`) rather than inside jit: its
  shapes are data-dependent by design — relay splices widen the hop
  axis mid-plan, the avail mask shrinks irreversibly, deep optima fall
  back to the scalar DFS — so only the fixed-shape event stepping
  crosses the jit boundary and the replan step reuses the exact code
  (and float behavior) the numpy backend is pinned by.
* `JaxPipelineEngine.execute` — PPT's pipeline stepper
  (`execute_pipeline_batch`'s twin): the topological min-scan unrolls
  the static depth levels inside the jitted while-loop body.

**Bandwidth epoch stacks.** The numpy engine refreshes a `(B, N, N)`
matrix stack lazily from each case's `BandwidthProcess`; a jitted loop
cannot call back into host rng, so epochs are *pre-sampled* into a
device-resident `(B, E, N, N)` tensor: recorded `BandwidthTrace` epochs
are used as-is, live processes are bulk-sampled with `sample_epochs`
(documented bit-identical to `matrix_at`), and static networks occupy a
single eternal epoch. A live case whose simulation outruns the sampled
horizon sets an overflow flag inside the loop; the engine then raises
`EpochHorizonError`, the caller restores any replan-mutated plans, the
horizon doubles, and the batch re-runs — with identical results, since
epoch matrices are pure functions of `(seed, epoch)`.

**Fan-in shares.** `IngressModel.share_weights` (Dirichlet splits) is
host rng too; with persistent shares the split is a pure function of
`(seed, receiver, fan-in)`, so the engine precomputes a
`(B, N, M + 1, M)` weight table covering every receiver that can see
fan-in >= 2 (a sound bound read off the compiled plans: concurrent
fan-in at a node never exceeds its per-round receiver-hop count, and
BMF relay splices only add fan-in-1 receivers). Non-persistent ingress
models fall back to the numpy engine.

**Bucketing + program reuse.** jit re-compiles per input shape, so the
adapters pad every batch axis (B, T, H, R, E, pipeline edges) up to the
next power of two with masked-out padding (zero-hop transfers, drained
edges, eternal-epoch bandwidth rows). Batches with differing round
counts therefore share one compiled program per (N, rounds-bucket) —
the cluster size N is the only raw shape dimension. Buffer donation is
enabled on non-CPU backends only (CPU XLA cannot consume donations and
would warn on every call).

Everything runs under `jax.experimental.enable_x64` so device floats
are the same float64 ops the numpy engine performs; the parity suite
(`tests/test_jax_engine.py`) pins all 8 schemes x 3 volatility regimes
to the reference engines at 1e-6 relative tolerance with identical
round counts.
"""
from __future__ import annotations

import functools
import types
import typing

import numpy as np

_EPS = 1e-9
_GUARD = 100_000
# device epoch stacks are capped; a batch that cannot fit falls back to
# the numpy engine rather than thrashing host memory
_MEM_LIMIT_BYTES = 256 * 1024 * 1024
_INITIAL_LIVE_EPOCHS = 64
_MAX_LIVE_EPOCHS = 8192


class EpochHorizonError(RuntimeError):
    """A live case outran the pre-sampled bandwidth epoch horizon."""


class JaxUnsupported(RuntimeError):
    """The batch cannot run on the jax engine (caller falls back)."""


_JAX_OK: bool | None = None


def jax_available() -> bool:
    """True when jax imports and can build arrays (checked once)."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax  # noqa: F401
            import jax.numpy as jnp

            jnp.zeros(1)
            _JAX_OK = True
        except Exception:  # pragma: no cover - env without a working jax
            _JAX_OK = False
    return _JAX_OK


def _pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1) — the shape-bucketing unit."""
    return 1 << max(int(n) - 1, 0).bit_length()


def _x64():
    from jax.experimental import enable_x64

    return enable_x64()


# ------------------------------------------------------------ jitted programs
_FNS: types.SimpleNamespace | None = None


def _build_fns() -> types.SimpleNamespace:
    import jax
    import jax.numpy as jnp
    from jax import lax

    # CPU XLA cannot consume donated buffers (it would warn per call);
    # on accelerators the per-call hop tensors and t0 are donated.
    donate = (0, 1, 2, 3) if jax.default_backend() != "cpu" else ()

    def epoch_state(t, ctx):
        """(bw, epoch_end, epoch) for every case at its own time `t` —
        the jit twin of `_BatchBandwidth.refresh` (recompute instead of
        refresh-on-crossing; epoch matrices are constant per epoch, so
        the values are identical)."""
        e_f = jnp.floor(t / ctx.interval)   # floor of true division ==
        e = e_f.astype(jnp.int64)           # BandwidthTrace.epoch_of
        idx = jnp.where(ctx.cycle, e % ctx.num_ep,
                        jnp.minimum(e, ctx.num_ep - 1))
        idx = jnp.clip(idx, 0, ctx.stack.shape[1] - 1)
        bw = ctx.stack[jnp.arange(ctx.stack.shape[0]), idx]
        return bw, (e_f + 1.0) * ctx.interval, e

    def fanin_rates(bw, u, v, act, ctx):
        """Contended rates for active (u -> v) pairs: the dense twin of
        `_group_structure` + `_contended_rates_grouped`. Group membership
        becomes a `(B, T, N)` one-hot match, in-group position a cumsum
        (same transfer-index order as the numpy stable sort), the group
        cap a masked max-reduction; the m == 1 degenerate case falls out
        of the same expression (weight 1, factor >= 1)."""
        B, N = bw.shape[0], bw.shape[1]
        bi = jnp.arange(B)[:, None]
        s = bw[bi, u, v]
        match = act[:, :, None] & (v[:, :, None] == jnp.arange(N))
        m_recv = match.sum(axis=1)                                 # (B, N)
        m_t = jnp.take_along_axis(m_recv, v, axis=1)               # (B, T)
        pos = jnp.take_along_axis(jnp.cumsum(match, axis=1),
                                  v[:, :, None], axis=2)[:, :, 0] - 1
        smax = jnp.max(jnp.where(match, s[:, :, None], -jnp.inf), axis=1)
        factor = jnp.maximum(ctx.floor[:, None],
                             1.0 - ctx.degrade[:, None] * (m_recv - 1))
        cap = jnp.take_along_axis(smax * factor, v, axis=1)
        w = ctx.shares[bi, v,
                       jnp.minimum(m_t, ctx.shares.shape[2] - 1),
                       jnp.clip(pos, 0, ctx.shares.shape[3] - 1)]
        return jnp.minimum(s, w * cap), s

    def round_events(hop_u, hop_v, n_hops, t0, ctx):
        """One round's event loop: the `execute_round_batch` while loop
        with the same per-iteration ops (refresh, rates, dt, debit,
        completion) over the whole padded batch."""
        B, T, H = hop_u.shape
        chunk_col = ctx.chunk[:, None]
        eps_chunk = _EPS * chunk_col

        def done_mask(hop_i):
            return (hop_i >= n_hops).all(axis=1)

        def cond(st):
            t, hop_i, left, ovf, it = st
            return (~done_mask(hop_i)).any() & (it < _GUARD)

        def body(st):
            t, hop_i, left, ovf, it = st
            done = done_mask(hop_i)
            bw, epoch_end, e = epoch_state(t, ctx)
            ovf = ovf | (ctx.can_ovf & ~done & (e >= ctx.num_ep)).any()
            act = hop_i < n_hops
            h = jnp.minimum(hop_i, H - 1)[:, :, None]
            u = jnp.take_along_axis(hop_u, h, axis=2)[:, :, 0]
            v = jnp.take_along_axis(hop_v, h, axis=2)[:, :, 0]
            eff, _ = fanin_rates(bw, u, v, act, ctx)
            rates = jnp.where(act, jnp.maximum(eff, 0.0), 0.0)
            cand = jnp.where(act & (rates > 0),
                             left / jnp.where(rates > 0, rates, 1.0),
                             jnp.inf)
            dt = jnp.minimum(epoch_end - t, cand.min(axis=1))
            dt = jnp.where(jnp.isfinite(dt) & (dt > 0), dt, _EPS)
            dt = jnp.where(done, 0.0, dt)
            left = left - rates * dt[:, None]
            t = t + dt
            compl = act & (left <= eps_chunk)
            hop_i = hop_i + compl
            left = jnp.where(compl, chunk_col, left)
            return t, hop_i, left, ovf, it + 1

        init = (t0, jnp.zeros((B, T), jnp.int64),
                jnp.broadcast_to(chunk_col, (B, T)),
                jnp.bool_(False), jnp.int64(0))
        t, hop_i, _, ovf, it = lax.while_loop(cond, body, init)
        return t, ovf, it, done_mask(hop_i).all()

    run_round = jax.jit(round_events, donate_argnums=donate)

    def rounds_scan(hop_u, hop_v, n_hops, t0, ctx):
        """All rounds of a batch as one `lax.scan` over the (padded)
        round axis; padding rounds have zero transfers and pass t
        through unchanged."""

        def step(carry, inp):
            t, ovf, mx, ok = carry
            hu, hv, nh = inp
            t2, o2, it, done = round_events(hu, hv, nh, t, ctx)
            return (t2, ovf | o2, jnp.maximum(mx, it), ok & done), t2

        init = (t0, jnp.bool_(False), jnp.int64(0), jnp.bool_(True))
        (_, ovf, mx, ok), tends = lax.scan(step, init,
                                           (hop_u, hop_v, n_hops))
        return tends, ovf, mx, ok

    run_rounds = jax.jit(rounds_scan, donate_argnums=donate)

    @functools.lru_cache(maxsize=None)
    def pipeline(dmax: int):
        """PPT pipeline stepper for a given tree depth (the depth-level
        min-scan unrolls statically, like the numpy `range(dmax, 0, -1)`
        loop in `execute_pipeline_batch`)."""

        def pipeline_events(child, parent, depth, left0, t0, ctx):
            B, E = child.shape
            N = ctx.stack.shape[2]
            chunk_col = ctx.chunk[:, None]
            bi = jnp.arange(B)[:, None]

            def cond(st):
                t, left, ovf, it = st
                return (left > _EPS * chunk_col).any() & (it < _GUARD)

            def body(st):
                t, left, ovf, it = st
                live = left > _EPS * chunk_col
                case_on = live.any(axis=1)
                bw, epoch_end, e = epoch_state(t, ctx)
                ovf = ovf | (ctx.can_ovf & case_on & (e >= ctx.num_ep)).any()
                rx_eff, s = fanin_rates(bw, child, parent, live, ctx)
                has_rx = (live[:, :, None]
                          & (parent[:, :, None] == jnp.arange(N))).any(axis=1)
                has_tx = (live[:, :, None]
                          & (child[:, :, None] == jnp.arange(N))).any(axis=1)
                rx_dup = jnp.where(jnp.take_along_axis(has_tx, parent, axis=1),
                                   ctx.duplex[:, None], 1.0)
                tx_dup = jnp.where(jnp.take_along_axis(has_rx, child, axis=1),
                                   ctx.duplex[:, None], 1.0)
                raw = jnp.minimum(jnp.maximum(rx_eff * rx_dup, 0.0),
                                  jnp.maximum(s * tx_dup, 0.0))
                raw_full = jnp.where(live, raw, 0.0)

                # iterative topological min-scan, deepest edges first
                node_supply = jnp.full((B, N), jnp.inf)
                eff = raw_full
                for d in range(dmax, 0, -1):
                    sel = live & (depth == d)
                    val = jnp.minimum(raw_full, node_supply[bi, child])
                    eff = jnp.where(sel, val, eff)
                    node_supply = node_supply.at[bi, parent].min(
                        jnp.where(sel, val, jnp.inf))
                rates = jnp.where(live, eff, 0.0)

                cand = jnp.where(live & (rates > 0),
                                 left / jnp.where(rates > 0, rates, 1.0),
                                 jnp.inf)
                dt = jnp.minimum(epoch_end - t, cand.min(axis=1))
                dt = jnp.where(jnp.isfinite(dt) & (dt > 0), dt, _EPS)
                dt = jnp.where(case_on, dt, 0.0)
                left = jnp.where(live, left - rates * dt[:, None], left)
                return t + dt, left, ovf, it + 1

            init = (t0, left0, jnp.bool_(False), jnp.int64(0))
            t, left, ovf, it = lax.while_loop(cond, body, init)
            return t, ovf, it, ~(left > _EPS * chunk_col).any()

        return jax.jit(pipeline_events,
                       donate_argnums=(3, 4) if donate else ())

    return types.SimpleNamespace(run_round=run_round,
                                 run_rounds=run_rounds,
                                 pipeline=pipeline)


def _fns() -> types.SimpleNamespace:
    global _FNS
    if _FNS is None:
        _FNS = _build_fns()
    return _FNS


# --------------------------------------------------------------- host engines
class _Ctx(typing.NamedTuple):
    """Pytree of per-batch device arrays (shapes use the padded Bp)."""

    stack: typing.Any      # (Bp, Ep, N, N) epoch matrices
    interval: typing.Any   # (Bp,) epoch length, inf = static network
    num_ep: typing.Any     # (Bp,) valid epochs in the stack
    cycle: typing.Any      # (Bp,) trace cycles (vs clamps) past the end
    can_ovf: typing.Any    # (Bp,) live case: sampled horizon can overflow
    chunk: typing.Any      # (Bp,)
    degrade: typing.Any    # (Bp,)
    floor: typing.Any      # (Bp,)
    duplex: typing.Any     # (Bp,)
    shares: typing.Any     # (Bp, N, M + 1, M) Dirichlet fan-in splits


class _EngineBase:
    """Shared device context: epoch stacks, ingress params, shares table."""

    def __init__(self, scenarios, num_nodes: int, need: np.ndarray,
                 mmax: int):
        if not jax_available():
            raise JaxUnsupported("jax is not importable")
        if any(not sc.ingress.persistent_shares for sc in scenarios):
            # epoch-keyed share redraws cannot be pretabulated
            raise JaxUnsupported("non-persistent ingress shares")
        self.scenarios = list(scenarios)
        self.B = len(self.scenarios)
        self.Bp = _pow2(self.B)
        self.N = int(num_nodes)
        self.live_epochs = _INITIAL_LIVE_EPOCHS
        self._shares = self._shares_table(need, int(mmax))
        self._chunk = self._padded([sc.chunk_mb for sc in self.scenarios], 1.0)
        self._degrade = self._padded(
            [sc.ingress.degrade for sc in self.scenarios], 0.0)
        self._floor = self._padded(
            [sc.ingress.floor for sc in self.scenarios], 1.0)
        self._duplex = self._padded(
            [sc.ingress.duplex for sc in self.scenarios], 1.0)
        self._rebuild_ctx()

    def _padded(self, vals, fill: float) -> np.ndarray:
        out = np.full(self.Bp, fill, dtype=float)
        out[: self.B] = vals
        return out

    def _shares_table(self, need: np.ndarray, mmax: int) -> np.ndarray:
        """(Bp, N, mmax + 1, mmax) Dirichlet weight table; slot
        [b, v, m, i] is sender i's share of an m-way fan-in at receiver
        v. m <= 1 slots are 1.0 (the degenerate group)."""
        m1 = max(mmax + 1, 2)
        W = np.zeros((self.Bp, self.N, m1, max(mmax, 1)))
        W[:, :, :, 0] = 1.0
        cache: dict = {}
        for b, sc in enumerate(self.scenarios):
            ing = sc.ingress
            for v in np.nonzero(need[b])[0]:
                for m in range(2, m1):
                    key = (ing.seed, ing.alpha, int(v), m)
                    ww = cache.get(key)
                    if ww is None:
                        ww = ing.share_weights(m, int(v), 0)
                        cache[key] = ww
                    W[b, int(v), m, :m] = ww
        return W

    def _rebuild_ctx(self) -> None:
        """(Re)build the device epoch stack at the current live horizon."""
        from repro.core.bandwidth import BandwidthTrace

        interval = np.full(self.Bp, np.inf)
        num_ep = np.ones(self.Bp, dtype=np.int64)
        cycle = np.zeros(self.Bp, dtype=bool)
        can = np.zeros(self.Bp, dtype=bool)
        per: list[np.ndarray] = []
        for b, sc in enumerate(self.scenarios):
            bwp = sc.bw
            if type(bwp) is BandwidthTrace:
                ep = np.asarray(bwp.epochs)
                interval[b] = bwp.change_interval
                cycle[b] = bwp.cycle
                num_ep[b] = ep.shape[0]
            elif bwp.change_interval is None or (
                    bwp.mode == "jitter" and bwp.jitter == 0.0):
                ep = np.asarray(bwp.base)[None]
            else:
                # bit-identical to matrix_at for epochs [0, live_epochs);
                # memoized on the process instance, so every scheme/batch
                # replaying this case shares one sampling pass
                ep = bwp.epochs_prefix(self.live_epochs)
                interval[b] = bwp.change_interval
                num_ep[b] = self.live_epochs
                can[b] = True
            per.append(ep)
        self._can_grow = bool(can.any())
        emax = _pow2(max((e.shape[0] for e in per), default=1))
        if self.Bp * emax * self.N * self.N * 8 > _MEM_LIMIT_BYTES:
            raise JaxUnsupported("epoch stack exceeds the device budget")
        stack = np.zeros((self.Bp, emax, self.N, self.N))
        for b, ep in enumerate(per):
            n = ep.shape[1]
            stack[b, : ep.shape[0], :n, :n] = ep
        with _x64():
            import jax.numpy as jnp

            self.ctx = _Ctx(*(
                jnp.asarray(a) for a in (
                    stack, interval, num_ep, cycle, can, self._chunk,
                    self._degrade, self._floor, self._duplex, self._shares,
                )))

    def grow(self):
        """Double the live-epoch horizon after an `EpochHorizonError`.
        Returns self, or None when the horizon/memory cap is hit (the
        caller then falls back to the numpy engine)."""
        if not self._can_grow or self.live_epochs * 2 > _MAX_LIVE_EPOCHS:
            return None
        self.live_epochs *= 2
        try:
            self._rebuild_ctx()
        except JaxUnsupported:
            return None
        return self

    def _finish(self, t_end, ovf, it, done) -> np.ndarray:
        t_end = np.asarray(t_end)
        if bool(ovf):
            raise EpochHorizonError(
                f"simulation outran the {self.live_epochs}-epoch horizon")
        if not bool(done):
            raise RuntimeError("simulator failed to converge")
        return t_end[: self.B]


class JaxRoundEngine(_EngineBase):
    """Round-scheme executor: drop-in for `execute_round_batch` (per
    round, between host replan steps) plus a whole-plan scan fast path."""

    def __init__(self, scenarios, num_nodes: int, arrays):
        need, mmax = _round_fanin(arrays, num_nodes, len(scenarios))
        super().__init__(scenarios, num_nodes, need, mmax)

    def _pad_round(self, hop_u, hop_v, n_hops, t0):
        B, T, H = hop_u.shape
        Tp, Hp = _pow2(T), _pow2(H)
        hu = np.zeros((self.Bp, Tp, Hp), dtype=np.int64)
        hv = np.zeros((self.Bp, Tp, Hp), dtype=np.int64)
        nh = np.zeros((self.Bp, Tp), dtype=np.int64)
        hu[:B, :T, :H] = hop_u
        hv[:B, :T, :H] = hop_v
        nh[:B, :T] = n_hops
        tt = np.zeros(self.Bp)
        tt[:B] = t0
        return hu, hv, nh, tt

    def execute_round(self, hop_u, hop_v, n_hops, t0) -> np.ndarray:
        hu, hv, nh, tt = self._pad_round(hop_u, hop_v, n_hops, t0)
        with _x64():
            import jax.numpy as jnp

            out = _fns().run_round(jnp.asarray(hu), jnp.asarray(hv),
                                   jnp.asarray(nh), jnp.asarray(tt), self.ctx)
        return self._finish(*out)

    def execute_rounds(self, hop_all_u, hop_all_v, n_hops_all,
                       t0) -> tuple[np.ndarray, np.ndarray]:
        """(round_times (R, B), t_end (B,)) for whole plans in one scan."""
        B, R, T, H = hop_all_u.shape
        if R == 0:
            return np.zeros((0, B)), np.asarray(t0, dtype=float).copy()
        Rp, Tp, Hp = _pow2(R), _pow2(T), _pow2(H)
        hu = np.zeros((Rp, self.Bp, Tp, Hp), dtype=np.int64)
        hv = np.zeros((Rp, self.Bp, Tp, Hp), dtype=np.int64)
        nh = np.zeros((Rp, self.Bp, Tp), dtype=np.int64)
        hu[:R, :B, :T, :H] = hop_all_u.transpose(1, 0, 2, 3)
        hv[:R, :B, :T, :H] = hop_all_v.transpose(1, 0, 2, 3)
        nh[:R, :B, :T] = n_hops_all.transpose(1, 0, 2)
        tt = np.zeros(self.Bp)
        tt[:B] = t0
        with _x64():
            import jax.numpy as jnp

            tends, ovf, mx, ok = _fns().run_rounds(
                jnp.asarray(hu), jnp.asarray(hv), jnp.asarray(nh),
                jnp.asarray(tt), self.ctx)
            tends = np.asarray(tends)
        self._finish(tends[-1], ovf, mx, ok)
        tends = tends[:, : B]
        rt = np.diff(np.concatenate([np.asarray(t0)[None, :], tends[:R]],
                                    axis=0), axis=0)
        return rt, tends[R - 1].copy()


class JaxPipelineEngine(_EngineBase):
    """PPT executor: drop-in for `execute_pipeline_batch`."""

    def __init__(self, scenarios, num_nodes: int, parent, edge_valid):
        need, mmax = _pipeline_fanin(parent, edge_valid, num_nodes)
        super().__init__(scenarios, num_nodes, need, mmax)

    def execute(self, child, parent, depth, edge_valid, t0) -> np.ndarray:
        B, E = child.shape
        Ep = _pow2(E)
        c = np.zeros((self.Bp, Ep), dtype=np.int64)
        p = np.zeros((self.Bp, Ep), dtype=np.int64)
        d = np.zeros((self.Bp, Ep), dtype=np.int64)
        left0 = np.zeros((self.Bp, Ep))
        c[:B, :E] = child
        p[:B, :E] = parent
        d[:B, :E] = depth
        left0[:B, :E] = np.where(edge_valid, self._chunk[:B, None], 0.0)
        tt = np.zeros(self.Bp)
        tt[:B] = t0
        dmax = int(depth.max()) if depth.size else 0
        with _x64():
            import jax.numpy as jnp

            out = _fns().pipeline(dmax)(
                jnp.asarray(c), jnp.asarray(p), jnp.asarray(d),
                jnp.asarray(left0), jnp.asarray(tt), self.ctx)
        return self._finish(*out)


# ----------------------------------------------------------- fan-in analysis
def _round_fanin(arrays, num_nodes: int,
                 B: int) -> tuple[np.ndarray, int]:
    """(need (B, N) bool, mmax): receivers that can see fan-in >= 2 and
    the batch-wide fan-in bound, read off the compiled plans. Concurrent
    fan-in at a node never exceeds its per-round receiver-hop count, and
    BMF relay splices only add fan-in-1 relay receivers, so counts taken
    before replanning stay a sound bound."""
    need = np.zeros((B, num_nodes), dtype=bool)
    mmax = 1
    for b, pa in enumerate(arrays):
        if not pa.num_transfers:
            continue
        counts = np.diff(pa.round_start).astype(np.int64)
        rid = np.repeat(np.arange(pa.num_rounds), counts)
        cols = np.arange(pa.t_path.shape[1])
        recv_sel = ((cols[None, :] >= 1)
                    & (cols[None, :] < pa.t_path_len[:, None]))
        keys = (rid[:, None] * num_nodes + pa.t_path)[recv_sel]
        cnt = np.bincount(keys, minlength=pa.num_rounds * num_nodes)
        cnt = cnt.reshape(pa.num_rounds, num_nodes)
        need[b] = (cnt >= 2).any(axis=0)
        mmax = max(mmax, int(cnt.max(initial=1)))
    return need, mmax


def _pipeline_fanin(parent, edge_valid,
                    num_nodes: int) -> tuple[np.ndarray, int]:
    B = parent.shape[0]
    need = np.zeros((B, num_nodes), dtype=bool)
    mmax = 1
    for b in range(B):
        cnt = np.bincount(parent[b][edge_valid[b]], minlength=num_nodes)
        need[b] = cnt >= 2
        mmax = max(mmax, int(cnt.max(initial=1)))
    return need, mmax


# ------------------------------------------------------------------ factories
def make_round_engine(scenarios, num_nodes: int, arrays):
    """A `JaxRoundEngine` for the batch, or None when it must fall back
    to the numpy engine (no jax, non-persistent shares, memory cap)."""
    if not jax_available():
        return None
    try:
        return JaxRoundEngine(scenarios, num_nodes, arrays)
    except JaxUnsupported:
        return None


def make_pipeline_engine(scenarios, num_nodes: int, parent, edge_valid):
    """A `JaxPipelineEngine` for the batch, or None (numpy fallback)."""
    if not jax_available():
        return None
    try:
        return JaxPipelineEngine(scenarios, num_nodes, parent, edge_valid)
    except JaxUnsupported:
        return None
