"""PPT — Parallel Pipeline Tree (Bai et al., ICPP'19) baseline.

PPT builds, *once, from the bandwidth snapshot at repair start*, a tree
rooted at the requestor spanning the k helpers; chunk slices are pipelined
down the tree, so steady-state repair rate = the tree's bottleneck edge
rate. PPT assumes a receiver's capacity divides *equally* among its
concurrent in-links (the assumption our paper criticizes via Fig. 2): the
tree is chosen to maximize the bottleneck under that assumption, but it is
*executed* under the simulator's real ingress model and bandwidth churn —
plan-once is exactly why PPT degrades in rapidly-changing networks
(paper Fig. 11/12).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.plan import Job, RepairPlan, Round, Transfer


@dataclasses.dataclass
class PPTTree:
    job: Job
    parent: dict[int, int]                 # helper/relay -> parent node
    children: dict[int, list[int]]

    @property
    def edges(self) -> list[tuple[int, int]]:
        return [(c, p) for c, p in self.parent.items()]

    def depths(self) -> dict[int, int]:
        """Hop distance of every tree node from the requestor root."""
        out: dict[int, int] = {}
        for node in self.parent:
            d, cur = 0, node
            while cur != self.job.requestor:
                cur = self.parent[cur]
                d += 1
            out[node] = d
        return out

    def assumed_bottleneck(self, bw: np.ndarray) -> float:
        bn = float("inf")
        for c, p in self.parent.items():
            fan_in = max(1, len(self.children.get(p, ())))
            bn = min(bn, bw[c, p] / fan_in)
        return bn


def ppt_round_plan(tree: PPTTree) -> RepairPlan:
    """Store-and-forward lowering of a pipeline tree to a `RepairPlan`.

    PPT executes as slice pipelining (no round structure), but the *bytes*
    it moves are well-defined: every tree node forwards the XOR-fold of
    its subtree's premultiplied terms to its parent. Lowering depth level
    d to round `dmax - d` (deepest first) yields an equivalent
    store-and-forward plan — by the time a node sends, all of its
    children's fragments have arrived and folded — so the byte data plane
    can execute and verify PPT repairs with the same machinery as the
    round schemes. Fan-in at interior nodes is real: validate with
    `max_recv_per_round` >= the tree's widest fan-in.
    """
    job = tree.job
    depths = tree.depths()
    dmax = max(depths.values(), default=0)
    terms: dict[int, set[int]] = {h: {h} for h in job.helpers}
    rounds = []
    for d in range(dmax, 0, -1):
        rnd = Round()
        for c in sorted(n for n, dd in depths.items() if dd == d):
            p = tree.parent[c]
            rnd.transfers.append(Transfer(
                src=c, dst=p, job=job.job_id, terms=frozenset(terms[c])))
            terms.setdefault(p, set()).update(terms[c])
            del terms[c]
        rounds.append(rnd)
    return RepairPlan(jobs=[job], rounds=rounds,
                      meta={"scheme": "ppt", "lowered_from": "pipeline-tree"})


def build_ppt_tree(job: Job, bw0: np.ndarray) -> PPTTree:
    """Greedy max-bottleneck attachment under PPT's equal-split assumption.

    PPT's model (quoted in the paper): "when multiple nodes send data to a
    node in parallel, the bandwidth of each link is the total bandwidth
    divided by the number of links" — i.e. the receiver's capacity (its
    best in-link) divides *equally* among concurrent in-links, regardless
    of each link's own rate. Under this belief fan-in looks cheap whenever
    helper-to-helper links are weak, so PPT happily builds multi-sender
    nodes — which the *real* ingress behaviour (Fig. 2: degraded total,
    skewed split) then punishes. That modeling gap is the paper's critique.

    This facade prices every (helper, attach-point) pair per greedy step
    as one `(H, V)` array expression (planner-layer idiom) instead of the
    historical nested-loop scan; the first-maximum argmax over the
    helper-major layout reproduces the scan's strict-`>` tie-breaking, so
    the tree built is identical.
    """
    root = job.requestor
    parent: dict[int, int] = {}
    children: dict[int, list[int]] = {root: []}
    attached = {root}
    remaining = list(job.helpers)
    capacity = bw0.max(axis=0)  # believed receiver capacity: best in-link

    def edge_rate(child: int, par: int, extra_child: bool) -> float:
        fan_in = len(children.get(par, ())) + (1 if extra_child else 0)
        if fan_in <= 1:
            return bw0[child, par]
        return capacity[par] / fan_in

    def bottleneck_to_root(node: int) -> float:
        bn = float("inf")
        cur = node
        while cur != root:
            p = parent[cur]
            bn = min(bn, edge_rate(cur, p, extra_child=False))
            cur = p
        return bn

    while remaining:
        att = list(attached)       # iteration order == historical scan order
        fan_in = np.array([len(children.get(v, ())) for v in att])
        # candidate edge h -> v priced with h as an extra child of v
        er = np.where(
            fan_in[None, :] == 0,
            bw0[np.ix_(remaining, att)],
            capacity[att][None, :] / np.maximum(fan_in[None, :] + 1, 1),
        )
        btr = np.array([
            bottleneck_to_root(v) if v != root else float("inf") for v in att
        ])
        rate = np.minimum(er, btr[None, :])
        hi, vi = np.unravel_index(int(rate.argmax()), rate.shape)
        h, v = remaining[hi], att[vi]
        parent[h] = v
        children.setdefault(v, []).append(h)
        children.setdefault(h, [])
        attached.add(h)
        remaining.remove(h)
    return PPTTree(job=job, parent=parent, children=children)
