"""Event-driven repair simulator under dynamic bandwidth.

This is the Mininet-equivalent test bench (the container has no multi-host
network): transfers progress continuously at rates set by the current
bandwidth epoch (BandwidthProcess) and receiver fan-in contention
(IngressModel); events are hop completions and bandwidth-change epochs.

Scheme dispatch:
  traditional / ppr / ppt / bmf        (single-node, paper Figs. 9, 11, 12)
  mppr / random / msrepair             (multi-node,  paper Fig. 10, Table II)

Online schemes (bmf, msrepair) re-run BMFRepair link optimization at every
round boundary with the *current* bandwidth matrix — the paper's central
"local optimum per timestamp tracks the changing network" mechanism.
Offline schemes (ppt notably) plan once from the t=0 snapshot.
"""
from __future__ import annotations

import dataclasses
import time as _time

import numpy as np

from repro.core import bmf
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.msrepair import (
    plan_mppr,
    plan_msrepair,
    plan_random,
    select_helpers_multi,
)
from repro.core.plan import Job, RepairPlan, Round, validate_plan
from repro.core.ppr import plan_ppr, plan_traditional
from repro.core.ppt import PPTTree, build_ppt_tree
from repro.ec.rs import RSCode

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Scenario:
    num_nodes: int                      # cluster size (>= code.n)
    code: RSCode
    failed: tuple[int, ...]
    bw: BandwidthProcess
    ingress: IngressModel
    chunk_mb: float = 16.0
    helpers: tuple[tuple[int, ...], ...] | None = None  # per-job override

    def make_jobs(self) -> list[Job]:
        # helper selection is a pure function of the (frozen) scenario and
        # is requested once per scheme — memoize the Job prototypes and
        # hand out a fresh list each call (Jobs themselves are read-only)
        jobs = getattr(self, "_jobs_cache", None)
        if jobs is None:
            failed = list(self.failed)
            if self.helpers is not None:
                helper_sets = [tuple(h) for h in self.helpers]
            elif len(failed) == 1:
                survivors = [x for x in range(self.code.n) if x not in failed]
                helper_sets = [tuple(survivors[: self.code.k])]
            else:
                helper_sets = select_helpers_multi(
                    self.code.n, self.code.k, failed)
            jobs = [
                Job(job_id=i, failed_node=f, requestor=f,
                    helpers=helper_sets[i])
                for i, f in enumerate(failed)
            ]
            object.__setattr__(self, "_jobs_cache", jobs)
        return list(jobs)


@dataclasses.dataclass
class SimResult:
    scheme: str
    total_time: float
    round_times: list[float]
    planning_time: float                # wall-clock seconds in plan/optimize
    plan: RepairPlan | None
    relay_hops: int = 0
    log: list[str] = dataclasses.field(default_factory=list)

    @property
    def num_rounds(self) -> int:
        return len(self.round_times)


# ------------------------------------------------------------- round engine
def execute_round(
    transfers,
    t0: float,
    bwp: BandwidthProcess,
    ingress: IngressModel,
    chunk_mb: float,
) -> float:
    """Advance simulated time until all transfers of a round complete.

    State is index-based (parallel lists over the transfer index), the
    scalar sibling of the batched `(B, T)` arrays in
    `repro.core.engine.vectorized.execute_round_batch`.
    """
    hops = [list(zip(tr.path[:-1], tr.path[1:])) for tr in transfers]
    n_hops = [len(h) for h in hops]
    hop = [0] * len(transfers)
    left = [chunk_mb] * len(transfers)
    t = t0
    guard = 0
    while any(hop[i] < n_hops[i] for i in range(len(transfers))):
        guard += 1
        if guard > 100_000:
            raise RuntimeError("simulator failed to converge")
        bw = bwp.matrix_at(t)
        epoch = bwp.epoch_of(t)
        active = [i for i in range(len(transfers)) if hop[i] < n_hops[i]]
        # fan-in contention per receiver (Fig. 2 model)
        by_recv: dict[int, list[int]] = {}
        for i in active:
            _, v = hops[i][hop[i]]
            by_recv.setdefault(v, []).append(i)
        rates = [0.0] * len(transfers)
        for v, senders in by_recv.items():
            standalone = np.array([bw[hops[i][hop[i]][0], v] for i in senders])
            eff = ingress.effective_rates(standalone, v, epoch)
            for i, r in zip(senders, eff):
                rates[i] = max(float(r), 0.0)
        # next event: a hop completes or the bandwidth epoch flips
        dt = bwp.epoch_end(t) - t
        for i in active:
            if rates[i] > 0:
                dt = min(dt, left[i] / rates[i])
        if not np.isfinite(dt) or dt <= 0:
            dt = _EPS      # e.g. an all-zero-bandwidth epoch: creep, don't
            #                keep dt = inf (which poisoned left with NaN)
        for i in active:
            left[i] -= rates[i] * dt
        t += dt
        for i in active:
            if left[i] <= _EPS * chunk_mb:
                hop[i] += 1            # store-and-forward: next hop restarts
                left[i] = chunk_mb
    return t


def pipeline_fill_latency(
    tree: PPTTree,
    bw0: np.ndarray,
    chunk_mb: float,
    slice_frac: float = 1.0 / 32.0,
) -> float:
    """Pipeline-fill latency of PPT's deepest path at the t=0 snapshot.

    Shared by `execute_pipeline` and the batched engine
    (`repro.core.engine.vectorized`) so the two stay expression-identical.
    """
    depth = max(tree.depths().values(), default=0)
    bn0 = max(tree.assumed_bottleneck(bw0), _EPS)
    return (depth - 1) * (chunk_mb * slice_frac) / bn0 if depth > 1 else 0.0


def execute_pipeline(
    tree: PPTTree,
    t0: float,
    bwp: BandwidthProcess,
    ingress: IngressModel,
    chunk_mb: float,
    slice_frac: float = 1.0 / 32.0,
) -> float:
    """PPT: slices stream down the tree concurrently on every edge.

    Edge (c -> p) carries the full chunk (RS aggregates stay block-sized);
    its instantaneous rate is its contended bandwidth (fan-in at p, Fig. 2)
    capped by the slowest edge in the subtree feeding c (a node forwards
    aggregate slices no faster than its children supply theirs). Repair
    completes when every edge has moved chunk_mb, plus the pipeline-fill
    latency of the deepest path.
    """
    t = t0
    edges = list(tree.parent.items())                    # (child, parent)
    left = {c: chunk_mb for c, _ in edges}
    children: dict[int, list[int]] = {}
    for c, p in edges:
        children.setdefault(p, []).append(c)
    # pipeline fill latency: deepest path at the initial snapshot
    t += pipeline_fill_latency(tree, bwp.matrix_at(t0), chunk_mb, slice_frac)

    guard = 0
    while any(v > _EPS * chunk_mb for v in left.values()):
        guard += 1
        if guard > 100_000:
            raise RuntimeError("pipeline simulation failed to converge")
        bw = bwp.matrix_at(t)
        epoch = bwp.epoch_of(t)
        # Node-level capacity split: every node's concurrent live links
        # (rx from children + tx to parent) share its capacity — interior
        # pipeline nodes receive and send at once, the "single node
        # accessing multiple links" effect the paper measured on Aliyun.
        live_edges = [c for c in left if left[c] > _EPS * chunk_mb]
        links_at: dict[int, list[tuple[int, str]]] = {}
        for c in live_edges:
            p = tree.parent[c]
            links_at.setdefault(p, []).append((c, "rx"))
            links_at.setdefault(c, []).append((c, "tx"))
        alloc: dict[tuple[int, str], float] = {}
        for v, links in links_at.items():
            standalone = np.array([bw[c, tree.parent[c]] for c, _ in links])
            kinds = tuple("rx" if kind == "rx" else "tx" for _, kind in links)
            eff = ingress.node_allocations(standalone, kinds, v, epoch)
            for (c, kind), r in zip(links, eff):
                alloc[(c, kind)] = max(float(r), 0.0)
        raw: dict[int, float] = {
            c: min(alloc[(c, "rx")], alloc[(c, "tx")]) for c in live_edges
        }

        def supply_rate(node: int) -> float:
            """Slowest live edge in the subtree rooted at `node`."""
            rate = float("inf")
            for c in children.get(node, ()):  # edges feeding `node`
                if left.get(c, 0.0) > _EPS * chunk_mb:
                    rate = min(rate, raw.get(c, 0.0), supply_rate(c))
            return rate

        rates = {
            c: min(raw.get(c, 0.0), supply_rate(c))
            for c in left if left[c] > _EPS * chunk_mb
        }
        dt = bwp.epoch_end(t) - t
        for c, r in rates.items():
            if r > 0:
                dt = min(dt, left[c] / r)
        if not np.isfinite(dt) or dt <= 0:
            dt = _EPS
        for c, r in rates.items():
            left[c] -= r * dt
        t += dt
    return t


# ---------------------------------------------------------------- simulator
SINGLE_SCHEMES = ("traditional", "ppr", "bmf", "ppt", "bmf_static")
MULTI_SCHEMES = ("mppr", "random", "msrepair")
ALL_SCHEMES = SINGLE_SCHEMES + MULTI_SCHEMES
# bmf_static: ablation — BMF's link optimization applied once from the
# t=0 snapshot (plan-once, like PPT) instead of per round. Isolates the
# paper's real-time-monitoring contribution from the relay mechanism.


def _idle_pool(sc: Scenario, jobs: list[Job]) -> list[int]:
    involved = {j.requestor for j in jobs} | {j.failed_node for j in jobs}
    return [x for x in range(sc.num_nodes) if x not in involved]


def plan_for_scheme(scheme: str, jobs: list[Job], *, random_seed: int = 0) -> RepairPlan:
    """Static round plan for any non-PPT scheme (PPT plans a pipeline tree,
    not rounds — see `run_scheme`)."""
    if scheme == "traditional":
        return plan_traditional(jobs[0])
    if scheme in ("ppr", "bmf", "bmf_static"):
        return plan_ppr(jobs[0])
    if scheme == "mppr":
        return plan_mppr(jobs)
    if scheme == "random":
        return plan_random(jobs, seed=random_seed)
    if scheme == "msrepair":
        return plan_msrepair(jobs)
    raise ValueError(f"unknown scheme {scheme!r}")


def run_scheme(
    sc: Scenario,
    scheme: str,
    *,
    bmf_optimize_all: bool = False,
    random_seed: int = 0,
) -> SimResult:
    """Plan + execute one scheme on one scenario.

    This is the shared round engine: `RepairSimulator.run` wraps it for the
    legacy single-scenario path and `repro.sim.sweep` calls it per
    (scenario, scheme) work item. Results are a pure function of
    (scenario, scheme, bmf_optimize_all, random_seed) — only
    `planning_time` is wall-clock and may vary between runs.
    """
    jobs = sc.make_jobs()
    plan_clock = 0.0

    tic = _time.perf_counter()
    if scheme == "ppt":
        tree = build_ppt_tree(jobs[0], sc.bw.matrix_at(0.0))
        plan_clock += _time.perf_counter() - tic
        t_end = execute_pipeline(tree, 0.0, sc.bw, sc.ingress, sc.chunk_mb)
        return SimResult(
            scheme=scheme, total_time=t_end, round_times=[t_end],
            planning_time=plan_clock, plan=None,
            log=[f"ppt tree edges={tree.edges}"],
        )
    plan = plan_for_scheme(scheme, jobs, random_seed=random_seed)
    plan_clock += _time.perf_counter() - tic

    validate_plan(
        plan, max_recv_per_round=len(jobs[0].helpers)
        if scheme == "traditional" else 1,
    )

    use_bmf = scheme in ("bmf", "msrepair", "bmf_static")
    static_plan_time = scheme == "bmf_static"
    t = 0.0
    round_times: list[float] = []
    relay_hops = 0
    log: list[str] = []
    executed_rounds: list[Round] = []
    for rnd in plan.rounds:
        if use_bmf:
            tic = _time.perf_counter()
            bw_now = sc.bw.matrix_at(0.0 if static_plan_time else t)
            idle = [
                x for x in _idle_pool(sc, jobs)
                if x not in rnd.nodes_in_use()
            ]
            rnd, stats = bmf.optimize_round(
                rnd, bw_now, idle, sc.chunk_mb,
                optimize_all=bmf_optimize_all,
            )
            plan_clock += _time.perf_counter() - tic
            relay_hops += sum(len(tr.relays) for tr in rnd.transfers)
            if stats.improved_links:
                log.append(
                    f"t={t:.2f}s round {len(round_times)}: BMF rerouted "
                    f"{stats.improved_links} link(s), est -{stats.time_saved:.2f}s"
                )
        t_end = execute_round(rnd.transfers, t, sc.bw, sc.ingress, sc.chunk_mb)
        round_times.append(t_end - t)
        t = t_end
        executed_rounds.append(rnd)

    final_plan = RepairPlan(jobs=plan.jobs, rounds=executed_rounds, meta=plan.meta)
    return SimResult(
        scheme=scheme, total_time=t, round_times=round_times,
        planning_time=plan_clock, plan=final_plan, relay_hops=relay_hops,
        log=log,
    )


class RepairSimulator:
    """Single-scenario façade over `run_scheme` (the legacy public API)."""

    SINGLE_SCHEMES = SINGLE_SCHEMES
    MULTI_SCHEMES = MULTI_SCHEMES

    def __init__(self, scenario: Scenario, *, bmf_optimize_all: bool = False,
                 random_seed: int = 0):
        self.sc = scenario
        self.bmf_optimize_all = bmf_optimize_all
        self.random_seed = random_seed

    def run(self, scheme: str) -> SimResult:
        return run_scheme(
            self.sc, scheme,
            bmf_optimize_all=self.bmf_optimize_all,
            random_seed=self.random_seed,
        )
