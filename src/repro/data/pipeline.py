"""Deterministic synthetic token pipeline.

Sequences follow a fixed seeded bigram Markov chain over the vocabulary, so
a language model has real structure to learn (loss decreases measurably in
a few hundred steps — used by examples/quickstart.py and the FT tests) and
every (step, host) batch is reproducible for elastic restarts: the stream
is addressed by step index, never by iterator state.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    branching: int = 8          # bigram successors per token


class SyntheticStream:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        v = min(cfg.vocab_size, 4096)   # active vocab (keeps tables small)
        self.active_vocab = v
        self.successors = rng.integers(0, v, size=(v, dcfg.branching))

    def batch_at(self, step: int, *, batch_size: int | None = None) -> dict:
        b = batch_size or self.shape.global_batch
        t = self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step]))
        seq = np.empty((b, t + 1), dtype=np.int32)
        seq[:, 0] = rng.integers(0, self.active_vocab, size=b)
        choices = rng.integers(0, self.dcfg.branching, size=(b, t))
        for i in range(t):
            seq[:, i + 1] = self.successors[seq[:, i], choices[:, i]]
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if self.cfg.is_encoder_decoder:
            rngf = np.random.default_rng(
                np.random.SeedSequence([self.dcfg.seed, step, 1]))
            batch["frames"] = rngf.standard_normal(
                (b, t, self.cfg.d_model)).astype(np.float32)
            td = min(self.cfg.max_decoder_len, t)
            batch["tokens"] = batch["tokens"][:, :td]
            batch["labels"] = batch["labels"][:, :td]
        if self.cfg.mrope:
            pos = np.broadcast_to(np.arange(t, dtype=np.int32)[None, :], (b, t))
            batch["pos3"] = np.broadcast_to(pos[None], (3, b, t)).copy()
            rngv = np.random.default_rng(
                np.random.SeedSequence([self.dcfg.seed, step, 2]))
            batch["vision_embeds"] = rngv.standard_normal(
                (b, min(256, t), self.cfg.d_model)).astype(np.float32)
        return batch

    def host_batch_at(self, step: int, host: int, num_hosts: int) -> dict:
        """Host-sharded slice of the global batch (data-parallel loading)."""
        full = self.batch_at(step)
        per = self.shape.global_batch // num_hosts
        sl = slice(host * per, (host + 1) * per)
        out = {}
        for k, v in full.items():
            out[k] = v[:, sl] if k == "pos3" else v[sl]
        return out
