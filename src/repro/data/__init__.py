"""Deterministic synthetic data pipeline."""
