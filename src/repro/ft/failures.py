"""Failure injection + straggler detection.

`FailureInjector` produces a seeded schedule of host/domain failures by
step index — the driver consults it each step and exercises the full
recovery path (EC checkpoint repair + elastic re-mesh) exactly as a real
cluster's health monitor would.

`StragglerMonitor` keeps an EWMA of per-host step durations and flags
hosts whose recent steps exceed `threshold` x the fleet median — the
training-side analogue of BMFRepair's reroute-the-slowest-link loop (the
repair-traffic side is handled inside the planners themselves).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    step: int
    domains: tuple[int, ...]           # failure domains lost at this step


class FailureInjector:
    def __init__(self, *, num_domains: int, rate_per_step: float = 0.0,
                 max_concurrent: int = 2, seed: int = 0,
                 scheduled: tuple[FailureEvent, ...] = ()):
        self.num_domains = num_domains
        self.rate = rate_per_step
        self.max_concurrent = max_concurrent
        self.seed = seed
        self.scheduled = {e.step: e for e in scheduled}

    def check(self, step: int) -> FailureEvent | None:
        if step in self.scheduled:
            return self.scheduled[step]
        if self.rate <= 0:
            return None
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        if rng.uniform() >= self.rate:
            return None
        k = int(rng.integers(1, self.max_concurrent + 1))
        domains = tuple(
            int(x) for x in rng.choice(self.num_domains, size=k, replace=False)
        )
        return FailureEvent(step=step, domains=domains)


class StragglerMonitor:
    def __init__(self, num_hosts: int, *, alpha: float = 0.2,
                 threshold: float = 1.8, min_steps: int = 5):
        self.ewma = np.zeros(num_hosts)
        self.count = np.zeros(num_hosts, dtype=int)
        self.alpha = alpha
        self.threshold = threshold
        self.min_steps = min_steps

    def record(self, host: int, duration: float) -> None:
        if self.count[host] == 0:
            self.ewma[host] = duration
        else:
            self.ewma[host] = (
                self.alpha * duration + (1 - self.alpha) * self.ewma[host])
        self.count[host] += 1

    def stragglers(self) -> list[int]:
        ready = self.count >= self.min_steps
        if ready.sum() < 2:
            return []
        med = float(np.median(self.ewma[ready]))
        return [int(h) for h in np.nonzero(
            ready & (self.ewma > self.threshold * med))[0]]
