"""Elastic re-meshing after host/pod loss.

Policy: the tensor axis is sacred (intra-pod ICI); capacity loss shrinks
the data axis (drop whole data-rows of the mesh) or drops a pod. Training
resumes from the latest EC checkpoint with the global batch either kept
(more grad accumulation) or scaled down proportionally.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def shrink_mesh(mesh: Mesh, lost_data_rows: int) -> Mesh:
    """Drop `lost_data_rows` rows of the data axis, keep other axes."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "data" not in axes:
        raise ValueError("mesh has no data axis")
    new_data = axes["data"] - lost_data_rows
    if new_data < 1:
        raise ValueError("cannot shrink data axis below 1")
    data_dim = mesh.axis_names.index("data")
    idx = [slice(None)] * mesh.devices.ndim
    idx[data_dim] = slice(0, new_data)
    return Mesh(mesh.devices[tuple(idx)], mesh.axis_names)


def drop_pod(mesh: Mesh, pod: int) -> Mesh:
    axes = list(mesh.axis_names)
    if "pod" not in axes:
        raise ValueError("mesh has no pod axis")
    pod_dim = axes.index("pod")
    devices = np.delete(mesh.devices, pod, axis=pod_dim)
    if devices.shape[pod_dim] == 0:
        raise ValueError("cannot drop the last pod")
    return Mesh(devices, mesh.axis_names)


def elastic_data_size(global_batch: int, old_hosts: int,
                      new_hosts: int) -> int:
    """Keep per-host batch constant; shrink global batch proportionally
    (rounded to a multiple of new_hosts)."""
    per = global_batch // old_hosts
    return max(per * new_hosts, new_hosts)


def reshard_state(state, mesh: Mesh, shardings):
    """Re-place a (host-local) state pytree onto a new mesh."""
    return jax.device_put(state, shardings) if shardings is not None else state
