"""Fault tolerance: failure injection, straggler detection, elastic re-mesh."""

from repro.ft.failures import FailureInjector, StragglerMonitor  # noqa: F401
from repro.ft.elastic import elastic_data_size, shrink_mesh  # noqa: F401
