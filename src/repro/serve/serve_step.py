"""Serve steps: prefill (prompt -> cache) and decode (one token against a
full-length cache). These are the artifacts the decode_* / long_* dry-run
cells lower; `generate` drives them for the runnable examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import whisper
from repro.models.sharding import MeshRules, NO_MESH


def make_decode_step(cfg: ArchConfig, rules: MeshRules = NO_MESH,
                     chunk: int = 4096):
    """(params, token, cache[, pos3]) -> (logits, new_cache)."""
    def decode_step(params, token, cache, pos3=None):
        return M.decode_step(params, cfg, token, cache, rules=rules,
                             chunk=chunk, pos3=pos3)
    return decode_step


def make_prefill(cfg: ArchConfig, rules: MeshRules = NO_MESH,
                 chunk: int = 1024, max_len: int | None = None):
    mod = M.family_module(cfg)

    def prefill(params, batch):
        if cfg.is_encoder_decoder:
            memory = whisper.encode(params, cfg, batch["frames"], rules=rules,
                                    chunk=chunk, remat=False)
            xk, xv = whisper.cross_kv(params, cfg, memory, rules=rules)
            b = batch["frames"].shape[0]
            cache = whisper.init_self_cache(cfg, b, cfg.max_decoder_len, rules)
            logits, cache = whisper.decode(
                params, cfg, batch["tokens"], xk=xk, xv=xv, self_cache=cache,
                rules=rules, chunk=chunk, remat=False)
            return logits[:, -1], {"self": cache, "xk": xk, "xv": xv}
        tokens = batch["tokens"]
        ml = max_len or tokens.shape[1] + 64
        if cfg.ssm_kind == "rwkv6":
            return mod.prefill(params, cfg, tokens, rules=rules)
        if cfg.shared_attn_every:
            return mod.prefill(params, cfg, tokens, ml, rules=rules,
                               attn_chunk=chunk)
        return mod.prefill(
            params, cfg, tokens, ml, rules=rules, chunk=chunk,
            pos3=batch.get("pos3"), vision_embeds=batch.get("vision_embeds"))
    return prefill


def make_whisper_decode_step(cfg: ArchConfig, rules: MeshRules = NO_MESH,
                             chunk: int = 4096):
    def decode_step(params, token, cache):
        logits, self_new = whisper.decode(
            params, cfg, token[:, None], xk=cache["xk"], xv=cache["xv"],
            self_cache=cache["self"], rules=rules, chunk=chunk, remat=False)
        return logits[:, 0], {"self": self_new, "xk": cache["xk"],
                              "xv": cache["xv"]}
    return decode_step


def generate(params, cfg: ArchConfig, batch: dict, steps: int, *,
             rules: MeshRules = NO_MESH, chunk: int = 1024,
             temperature: float = 0.0, key=None):
    """Greedy/sampled generation for the examples. Returns (B, steps)."""
    prefill = make_prefill(cfg, rules, chunk=chunk,
                           max_len=batch["tokens"].shape[1] + steps
                           if "tokens" in batch else None)
    logits, cache = prefill(params, batch)
    if cfg.is_encoder_decoder:
        step_fn = make_whisper_decode_step(cfg, rules, chunk)
    else:
        step_fn = make_decode_step(cfg, rules, chunk)
    outs = []
    b = logits.shape[0]
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            token = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            token = jnp.argmax(logits, axis=-1)
        outs.append(token)
        if cfg.mrope:
            pos = batch["tokens"].shape[1] + i
            pos3 = jnp.full((3, b, 1), pos, jnp.int32)
            logits, cache = step_fn(params, token, cache, pos3)
        else:
            logits, cache = step_fn(params, token, cache)
    return jnp.stack(outs, axis=1)
