"""Serving substrate: prefill/decode steps with sharded KV caches."""
