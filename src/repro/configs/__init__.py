"""Per-architecture configs (assigned pool) + shape registry."""

from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    applicable_shapes,
    get_arch,
)
