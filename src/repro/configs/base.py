"""Architecture & shape configuration registry.

One module per assigned architecture lives next to this file; each exports
`CONFIG: ArchConfig` built from the public spec. `reduced()` returns the
CPU-smoke-test variant of the same family (same code paths, tiny sizes).
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    moe: MoEConfig | None = None
    # attention structure
    attn_kind: str = "full"        # full | sliding | none
    sliding_window: int = 1024
    global_every: int = 0          # gemma3: 1 global layer per this many (5:1 -> 6)
    # state-space / hybrid
    ssm_kind: str = ""             # rwkv6 | mamba2
    ssm_state: int = 0
    shared_attn_every: int = 0     # zamba2: shared attn block cadence
    # encoder-decoder
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    max_decoder_len: int = 512     # whisper: decoder text length cap
    # vlm
    mrope: bool = False
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    # misc
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / mostly-sliding-window)."""
        return self.ssm_kind != "" or (
            self.attn_kind == "sliding" and self.global_every > 0
        ) or self.attn_kind == "sliding"

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes: dict = dict(
            num_layers=min(self.num_layers, 4 if self.shared_attn_every else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
        )
        if self.num_kv_heads == self.num_heads:       # MHA stays MHA
            changes["num_kv_heads"] = 4
        if self.num_kv_heads == 1:                    # MQA stays MQA
            changes["num_kv_heads"] = 1
        if self.moe:
            # capacity_factor >= E/top_k -> capacity == seq_len: no token
            # dropping, so decode matches full forward exactly in tests
            changes["moe"] = MoEConfig(num_experts=4, top_k=2, capacity_factor=4.0)
        if self.is_encoder_decoder:
            changes["encoder_layers"] = 2
            changes["max_decoder_len"] = 16
        if self.ssm_kind == "mamba2":
            changes["ssm_state"] = 16
            changes["num_heads"] = 4                  # mamba2 heads
        if self.shared_attn_every:
            changes["shared_attn_every"] = 2
        if self.attn_kind == "sliding":
            changes["sliding_window"] = 8
        if self.mrope:
            changes["mrope_sections"] = (2, 3, 3)   # sums to reduced hd/2
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = (
    "grok1_314b",
    "moonlight_16b_a3b",
    "gemma_2b",
    "smollm_360m",
    "qwen2_15b",
    "gemma3_4b",
    "whisper_medium",
    "rwkv6_16b",
    "qwen2vl_2b",
    "zamba2_7b",
)


def get_arch(arch_id: str) -> ArchConfig:
    arch_id = arch_id.replace("-", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The assigned (arch x shape) cells that actually lower.

    long_500k is restricted to sub-quadratic archs per the assignment
    (pure full-attention archs skip it; see DESIGN.md section 6).
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out
