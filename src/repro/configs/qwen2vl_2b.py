"""Qwen2-VL 2B — qwen2 backbone, M-RoPE, patch frontend stubbed
[arXiv:2409.12191; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2vl_2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128, qkv_bias=True,
    mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
)
