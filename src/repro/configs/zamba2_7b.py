"""Zamba2-7B — Mamba2 backbone + shared attention block every 6 layers,
ssm_state=64 [arXiv:2411.15242; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2_7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_kind="mamba2", ssm_state=64, shared_attn_every=6,
    rope_theta=10_000.0,
)
