"""Moonshot Moonlight-16B-A3B — MoE 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="moonlight_16b_a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    moe=MoEConfig(num_experts=64, top_k=6),
    rope_theta=50_000.0,
)
