"""RWKV6 (Finch) 1.6B — attention-free, data-dependent decay
[arXiv:2404.05892; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_16b", family="ssm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=7168, vocab_size=65536, head_dim=64,
    attn_kind="none", ssm_kind="rwkv6",
)
