"""Gemma3 4B — 5:1 local(1024-window):global, 128k ctx [hf:google/gemma-3; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    d_ff=10240, vocab_size=262144, head_dim=256,
    act="gelu", attn_kind="sliding", sliding_window=1024, global_every=6,
    rope_theta=1_000_000.0,
)
