"""Whisper-medium — enc-dec, conv frontend stubbed (precomputed frame
embeddings via input_specs) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=51865, head_dim=64,
    act="gelu", is_encoder_decoder=True, encoder_layers=24,
    max_decoder_len=448,
)
