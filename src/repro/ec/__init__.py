"""Erasure-coding substrate: GF(256) arithmetic, RS codes, bit-plane layout."""

from repro.ec import bitplane, gf256, rs, stripe  # noqa: F401
