"""Systematic Reed-Solomon (n, k) codes over GF(256).

Construction: Vandermonde matrix V[i, j] = alpha_i^j (alpha_i = i) reduced to
systematic form (top k rows = identity) by right-multiplying with the inverse
of its top k x k block. MDS for n <= 256: any k rows remain invertible.

Node indexing convention throughout the repo: nodes 0..k-1 hold data blocks
D1..Dk, nodes k..n-1 hold parity blocks P1..P(n-k).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.ec import gf256


@functools.lru_cache(maxsize=None)
def generator_matrix(n: int, k: int) -> np.ndarray:
    """(n, k) systematic generator matrix; rows 0..k-1 are identity."""
    if not (0 < k < n <= 256):
        raise ValueError(f"invalid RS parameters (n={n}, k={k})")
    vander = np.zeros((n, k), dtype=np.uint8)
    for i in range(n):
        for j in range(k):
            vander[i, j] = gf256.gf_pow(i + 1, j)
    top_inv = gf256.gf_mat_inv(vander[:k, :k])
    gen = np.zeros((n, k), dtype=np.uint8)
    for i in range(n):
        # gen[i] = vander[i] @ top_inv over GF(256)
        acc = np.zeros(k, dtype=np.uint8)
        for j in range(k):
            c = int(vander[i, j])
            if c:
                acc ^= gf256.MUL_TABLE[c, top_inv[j]]
        gen[i] = acc
    assert np.array_equal(gen[:k], np.eye(k, dtype=np.uint8))
    return gen


@dataclasses.dataclass(frozen=True)
class RSCode:
    """An (n, k) systematic RS code with helpers for repair planning."""

    n: int
    k: int

    @property
    def m(self) -> int:
        return self.n - self.k

    @property
    def generator(self) -> np.ndarray:
        return generator_matrix(self.n, self.k)

    # ------------------------------------------------------------------ encode
    def encode(self, data_blocks: np.ndarray) -> np.ndarray:
        """(k, nbytes) data -> (n, nbytes) codeword (data || parity)."""
        data_blocks = np.asarray(data_blocks, dtype=np.uint8)
        assert data_blocks.shape[0] == self.k
        parity = gf256.gf_matmul_np(self.generator[self.k:], data_blocks)
        return np.concatenate([data_blocks, parity], axis=0)

    def parity_coeffs(self) -> np.ndarray:
        """(n-k, k) coefficients mapping data blocks to parity blocks."""
        return self.generator[self.k:].copy()

    # ------------------------------------------------------------------ repair
    def repair_coeffs(
        self, failed: tuple[int, ...] | list[int], helpers: tuple[int, ...] | list[int]
    ) -> np.ndarray:
        """(|failed|, k) coefficients: lost block f = sum_j coeff[f, j] * helper_j.

        `helpers` must be exactly k surviving node ids. Works for any mix of
        data/parity failures (MDS property).
        """
        failed = tuple(failed)
        helpers = tuple(helpers)
        if len(helpers) != self.k:
            raise ValueError(f"need exactly k={self.k} helpers, got {len(helpers)}")
        if set(failed) & set(helpers):
            raise ValueError("helpers overlap failed nodes")
        gen = self.generator
        sub = gen[list(helpers), :]                     # (k, k): helpers in terms of data
        sub_inv = gf256.gf_mat_inv(sub)                 # data in terms of helpers
        # lost row i (in terms of data) composed with data-in-terms-of-helpers:
        out = np.zeros((len(failed), self.k), dtype=np.uint8)
        for fi, f in enumerate(failed):
            acc = np.zeros(self.k, dtype=np.uint8)
            for j in range(self.k):
                c = int(gen[f, j])
                if c:
                    acc ^= gf256.MUL_TABLE[c, sub_inv[j]]
            out[fi] = acc
        return out

    def repair_coeffs_batch(
        self, failed: np.ndarray, helpers: np.ndarray
    ) -> np.ndarray:
        """Batched single-failure repair coefficients.

        `failed` is (J,) lost block ids, `helpers` (J, k) helper block ids
        (each row exactly k distinct survivors of its own failure). Returns
        (J, k) uint8 coefficients, row j aligned with `helpers[j]` —
        identical to `repair_coeffs((failed[j],), helpers[j])[0]` but the
        whole batch shares one lockstep Gauss-Jordan
        (`gf256.gf_mat_inv_batch`) instead of J scalar inversions. This is
        the data-plane engine's entry point: one call covers every job of
        a batch of compiled plans.
        """
        failed = np.asarray(failed, dtype=np.int64).reshape(-1)
        helpers = np.asarray(helpers, dtype=np.int64)
        if failed.size == 0:
            return np.zeros((0, self.k), dtype=np.uint8)
        if helpers.shape != (failed.size, self.k):
            raise ValueError(
                f"helpers must be ({failed.size}, k={self.k}), "
                f"got {helpers.shape}")
        if (helpers == failed[:, None]).any():
            raise ValueError("helpers overlap failed nodes")
        gen = self.generator
        sub_inv = gf256.gf_mat_inv_batch(gen[helpers])      # (J, k, k)
        # out[j] = XOR_i gen[failed[j], i] (*) sub_inv[j, i, :]
        lost = gen[failed]                                  # (J, k)
        return np.bitwise_xor.reduce(
            gf256.MUL_TABLE[lost[:, :, None], sub_inv], axis=1)

    def reconstruct(
        self,
        failed: list[int],
        helpers: list[int],
        helper_blocks: np.ndarray,
    ) -> np.ndarray:
        """Decode lost blocks from k helper blocks. (|failed|, nbytes)."""
        coeff = self.repair_coeffs(tuple(failed), tuple(helpers))
        return gf256.gf_matmul_np(coeff, helper_blocks)

    def decode_all(self, present: dict[int, np.ndarray]) -> np.ndarray:
        """Recover the k data blocks from any >=k present blocks."""
        if len(present) < self.k:
            raise ValueError("not enough surviving blocks")
        helpers = sorted(present)[: self.k]
        blocks = np.stack([present[h] for h in helpers])
        failed = [i for i in range(self.k) if i not in present]
        if not failed:
            return np.stack([present[i] for i in range(self.k)])
        repaired = self.reconstruct(failed, helpers, blocks)
        out = []
        ri = 0
        for i in range(self.k):
            if i in present:
                out.append(present[i])
            else:
                out.append(repaired[ri])
                ri += 1
        return np.stack(out)
