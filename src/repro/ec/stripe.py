"""Stripe construction & placement across failure domains.

Used by the EC checkpoint layer: a logical blob is split into fixed-size
chunks; every k consecutive chunks form a stripe, extended with n-k parity
chunks. Placement rotates the parity position RAID-5 style so repair load
spreads, and guarantees the n blocks of a stripe land on n distinct failure
domains (hosts or pods).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.ec.rs import RSCode


@dataclasses.dataclass(frozen=True)
class Stripe:
    stripe_id: int
    code: RSCode
    # block b of this stripe (0..n-1; <k data, >=k parity) lives on node_ids[b]
    node_ids: tuple[int, ...]

    @property
    def data_nodes(self) -> tuple[int, ...]:
        return self.node_ids[: self.code.k]

    @property
    def parity_nodes(self) -> tuple[int, ...]:
        return self.node_ids[self.code.k:]

    def block_on_node(self, node: int) -> int | None:
        try:
            return self.node_ids.index(node)
        except ValueError:
            return None

    def block_map(self, num_domains: int) -> np.ndarray:
        """(num_domains,) node -> block position under this placement
        (-1 for domains holding no block of this stripe) — the `block_of`
        argument the byte data plane executes against."""
        if num_domains < self.code.n:
            raise ValueError(
                f"stripe spans {self.code.n} domains, have {num_domains}")
        out = np.full(num_domains, -1, dtype=np.int64)
        out[list(self.node_ids)] = np.arange(self.code.n)
        return out

    def perm(self, num_domains: int) -> np.ndarray:
        """(num_domains,) permutation from planner node ids (block b on
        node b, relays after) to this stripe's failure domains: block
        holders map onto `node_ids`, the relay pool onto the remaining
        domains in sorted order. Feed it to
        `repro.core.engine.arrays.relabel_plan_nodes` to replay a
        logical plan against the placed stripe."""
        n = self.code.n
        if num_domains < n:
            raise ValueError(
                f"stripe spans {n} domains, have {num_domains}")
        out = np.full(num_domains, -1, dtype=np.int64)
        out[:n] = self.node_ids
        out[n:] = sorted(set(range(num_domains)) - set(self.node_ids))
        return out


def place_stripes(
    num_stripes: int, code: RSCode, num_domains: int, *, rotate: bool = True
) -> list[Stripe]:
    """Assign each stripe's n blocks to n distinct failure domains."""
    if num_domains < code.n:
        raise ValueError(
            f"need >= n={code.n} failure domains, have {num_domains}"
        )
    stripes = []
    for s in range(num_stripes):
        base = (s * code.n) % num_domains if rotate else 0
        nodes = tuple((base + i) % num_domains for i in range(code.n))
        stripes.append(Stripe(stripe_id=s, code=code, node_ids=nodes))
    return stripes


def split_blob(blob: np.ndarray, k: int, chunk_bytes: int) -> np.ndarray:
    """Flatten a byte blob into (num_stripes, k, chunk_bytes), zero-padded."""
    blob = np.asarray(blob, dtype=np.uint8).reshape(-1)
    stripe_bytes = k * chunk_bytes
    num_stripes = max(1, -(-blob.size // stripe_bytes))
    padded = np.zeros(num_stripes * stripe_bytes, dtype=np.uint8)
    padded[: blob.size] = blob
    return padded.reshape(num_stripes, k, chunk_bytes)


def join_blob(chunks: np.ndarray, total_bytes: int) -> np.ndarray:
    """(num_stripes, k, chunk_bytes) -> original byte blob."""
    return np.asarray(chunks, dtype=np.uint8).reshape(-1)[:total_bytes]
