"""Bit-plane (bit-sliced) layout for TPU-native GF(256) arithmetic.

A GF(256) multiply by a constant c is linear over GF(2): viewing a byte as a
bit-vector, out = M_c @ in with M_c an 8x8 bit matrix (`gf256.mul_bitmatrix`).
If we slice a chunk of B bytes into 8 planes -- plane b holds bit b of every
byte, packed 32 bits per uint32 lane -- then multiply-accumulate over shards
becomes pure AND/XOR on uint32 vectors: no gathers, no byte shuffles, ideal
for the TPU VPU (see DESIGN.md section 3/4).

Packing convention: plane word w covers bytes [32w, 32w+32); byte 32w+j
contributes bit j of the word (little bit order). Chunks are padded to a
multiple of 32 bytes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.ec import gf256

BYTES_PER_WORD = 4
BYTES_PER_LANE = 32  # bits per uint32 word


def padded_len(nbytes: int) -> int:
    return (nbytes + BYTES_PER_LANE - 1) // BYTES_PER_LANE * BYTES_PER_LANE


# --------------------------------------------------------------------- numpy
def pack_np(data: np.ndarray) -> np.ndarray:
    """(..., nbytes) uint8 -> (..., 8, W) uint32 bit-planes; W = nbytes/32."""
    data = np.asarray(data, dtype=np.uint8)
    nbytes = data.shape[-1]
    pad = padded_len(nbytes) - nbytes
    if pad:
        data = np.concatenate(
            [data, np.zeros(data.shape[:-1] + (pad,), dtype=np.uint8)], axis=-1
        )
    w = data.shape[-1] // BYTES_PER_LANE
    grouped = data.reshape(data.shape[:-1] + (w, BYTES_PER_LANE)).astype(np.uint32)
    shifts = np.arange(BYTES_PER_LANE, dtype=np.uint32)
    planes = []
    for b in range(8):
        bits = (grouped >> b) & 1
        planes.append((bits << shifts).sum(axis=-1, dtype=np.uint32))
    return np.stack(planes, axis=-2)  # (..., 8, W)


def unpack_np(planes: np.ndarray, nbytes: int) -> np.ndarray:
    """(..., 8, W) uint32 -> (..., nbytes) uint8."""
    planes = np.asarray(planes, dtype=np.uint32)
    w = planes.shape[-1]
    shifts = np.arange(BYTES_PER_LANE, dtype=np.uint32)
    out = np.zeros(planes.shape[:-2] + (w, BYTES_PER_LANE), dtype=np.uint8)
    for b in range(8):
        bits = (planes[..., b, :, None] >> shifts) & 1
        out |= (bits << b).astype(np.uint8)
    return out.reshape(planes.shape[:-2] + (w * BYTES_PER_LANE,))[..., :nbytes]


# ----------------------------------------------------------------------- jnp
def pack_jnp(data: jnp.ndarray) -> jnp.ndarray:
    """jnp mirror of pack_np (on-device bit-slicing)."""
    nbytes = data.shape[-1]
    pad = padded_len(nbytes) - nbytes
    if pad:
        data = jnp.concatenate(
            [data, jnp.zeros(data.shape[:-1] + (pad,), dtype=jnp.uint8)], axis=-1
        )
    w = data.shape[-1] // BYTES_PER_LANE
    grouped = data.reshape(data.shape[:-1] + (w, BYTES_PER_LANE)).astype(jnp.uint32)
    shifts = jnp.arange(BYTES_PER_LANE, dtype=jnp.uint32)
    planes = [
        jnp.sum(((grouped >> b) & jnp.uint32(1)) << shifts, axis=-1, dtype=jnp.uint32)
        for b in range(8)
    ]
    return jnp.stack(planes, axis=-2)


def unpack_jnp(planes: jnp.ndarray, nbytes: int) -> jnp.ndarray:
    w = planes.shape[-1]
    shifts = jnp.arange(BYTES_PER_LANE, dtype=jnp.uint32)
    acc = jnp.zeros(planes.shape[:-2] + (w, BYTES_PER_LANE), dtype=jnp.uint8)
    for b in range(8):
        bits = ((planes[..., b, :, None] >> shifts) & 1).astype(jnp.uint8)
        acc = acc | (bits << b)
    return acc.reshape(planes.shape[:-2] + (w * BYTES_PER_LANE,))[..., :nbytes]


# ------------------------------------------------------------------ bitmatrix
def coeff_to_masks_np(coeff: np.ndarray) -> np.ndarray:
    """(m, k) GF(256) coefficients -> (m, k, 8, 8) uint32 AND-masks.

    masks[o, i, bi, bj] = 0xFFFFFFFF if bit (bi, bj) of the multiply-by-
    coeff[o, i] bit-matrix is set else 0. Kernel computes
    out_plane[o, bi] ^= data_plane[i, bj] & masks[o, i, bi, bj].
    """
    coeff = np.asarray(coeff, dtype=np.uint8)
    m, k = coeff.shape
    masks = np.zeros((m, k, 8, 8), dtype=np.uint32)
    for o in range(m):
        for i in range(k):
            bm = gf256.mul_bitmatrix(int(coeff[o, i]))  # (8, 8) 0/1
            masks[o, i] = bm.astype(np.uint32) * np.uint32(0xFFFFFFFF)
    return masks
