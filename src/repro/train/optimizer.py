"""AdamW with optimizer state sharded like the parameters (Zero-3 style —
the MeshRules put every large tensor on the fsdp x tensor grid, so m/v
inherit the same PartitionSpecs), plus an int8 error-feedback gradient
compressor for the bandwidth-starved pod (DCN) axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.peak_lr * (
        cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, dtype=jnp.float32) -> dict:
    """dtype=bfloat16 halves optimizer HBM (the grok-314B single-pod fit;
    see EXPERIMENTS.md section Perf) at a small convergence-noise cost —
    update math still runs in f32."""
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def opt_logical(logical_params) -> dict:
    """m/v shard exactly like their parameters."""
    return {"m": logical_params, "v": logical_params}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state, step):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------- int8 EF gradient compress
def init_ef_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef_state):
    """Error-feedback int8 quantization: g_q = Q(g + e); e' = (g + e) - g_q.

    On real hardware the int8 payload is what crosses the pod (DCN) axis —
    a 4x byte reduction on the slowest links; here the quantization error
    and its feedback loop are exact, so convergence impact is real and
    testable (tests/test_train.py).
    """
    def q(g, e):
        total = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(total)), 1e-12) / 127.0
        q8 = jnp.clip(jnp.round(total / scale), -127, 127).astype(jnp.int8)
        deq = q8.astype(jnp.float32) * scale
        return deq.astype(g.dtype), total - deq

    out = jax.tree.map(q, grads, ef_state)
    new_g = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
