"""Training substrate: AdamW (Zero-sharded), gradient compression hooks,
microbatched train step."""
