"""Microbatched, remat'd train step with sharded state.

TrainState = {"params", "opt": {m, v}, "ef": error-feedback (optional),
"step"}. The step function is built once per (arch x mesh) and jitted with
in/out shardings derived from the logical trees — the same artifact the
multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
import jax.numpy as _jnp
from repro.models.sharding import MeshRules, NO_MESH, tree_constrain, tree_specs
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    microbatches: int = 1
    remat: bool = True
    attn_chunk: int = 1024
    compress_grads: bool = False
    opt_dtype: str = "float32"      # "bfloat16": half-size m/v (grok fit)


def init_state(key, cfg: ArchConfig, tcfg: TrainConfig):
    params = M.init_params(key, cfg)
    od = _jnp.bfloat16 if tcfg.opt_dtype == "bfloat16" else _jnp.float32
    state = {
        "params": params,
        "opt": opt.init_opt_state(params, od),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.compress_grads:
        state["ef"] = opt.init_ef_state(params)
    return state


def state_logical(cfg: ArchConfig, tcfg: TrainConfig, rules: MeshRules):
    lp = M.logical_params(cfg, rules)
    s = {"params": lp, "opt": opt.opt_logical(lp), "step": ()}
    if tcfg.compress_grads:
        s["ef"] = lp
    return s


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig,
                    rules: MeshRules = NO_MESH):
    logical_p = M.logical_params(cfg, rules)

    def constrain_grads(grads):
        # pin gradients to the parameter sharding: the data-axis reduction
        # lowers to reduce-scatter into the FSDP shards instead of a full
        # all-reduce of every weight gradient (see EXPERIMENTS.md Perf)
        return tree_constrain(rules, grads, logical_p)

    def loss_fn(params, batch):
        return M.train_loss(params, cfg, batch, rules=rules,
                            chunk=tcfg.attn_chunk, remat=tcfg.remat)

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(tcfg.microbatches, b // tcfg.microbatches,
                                 *x.shape[1:])
            mb = {}
            for k, v in batch.items():
                if k == "pos3":
                    mb[k] = jnp.moveaxis(
                        v.reshape(3, tcfg.microbatches, -1, v.shape[-1]), 1, 0)
                else:
                    mb[k] = split(v)

            def micro(acc, mbatch):
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / tcfg.microbatches,
                    acc, grads)
                return acc, loss

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zero, mb)
            # constrain AFTER accumulation: one reduce-scatter for the whole
            # step, not one per microbatch (8x the wire bytes — measured,
            # see EXPERIMENTS.md Perf/grok iteration 3)
            grads = constrain_grads(grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_grads(grads)

        new_state = dict(state)
        if tcfg.compress_grads:
            grads, new_state["ef"] = opt.compress_grads(grads, state["ef"])
        new_params, new_opt, info = opt.adamw_update(
            tcfg.adamw, params, grads, state["opt"], state["step"])
        new_state.update(
            params=new_params, opt=new_opt, step=state["step"] + 1)
        metrics = {"loss": loss, **info}
        return new_state, metrics

    return train_step
