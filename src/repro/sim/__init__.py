"""Scenario-sweep subsystem: statistical evaluation over *families* of
repair scenarios.

The paper's claim — per-round monitoring (BMFRepair/MSRepair) tracks a
rapidly-changing network better than plan-once schemes (PPT) and static
pipelines (PPR) — is a distributional statement. This layer provides the
substrate to test it at scale:

* `repro.sim.suite`  — `ScenarioSuite` generators: parameter grids,
  Monte-Carlo sampling over codes / cluster sizes / volatility regimes /
  failure patterns, and trace-replay of recorded bandwidth epochs.
* `repro.sim.sweep`  — the batched sweep engine: runs every (scenario,
  scheme) pair of a suite concurrently (serial / thread / process
  dispatch), with deterministic per-scenario seeding, and aggregates
  per-scheme time distributions, speedup CDFs and planning-overhead stats.

Layering: ec -> core -> sim -> benchmarks. `sim` depends only on
`repro.core` (numpy-only — sweep workers never import JAX).
"""
from repro.sim.suite import (  # noqa: F401
    FAILURE_PATTERNS,
    VOLATILITY_REGIMES,
    GridSuite,
    MonteCarloSuite,
    SampleSpace,
    ScenarioCase,
    ScenarioSuite,
    TraceSuite,
    sample_failures,
)
from repro.sim.sweep import (  # noqa: F401
    ByteVerification,
    CaseResult,
    SchemeStats,
    SweepResult,
    run_sweep,
)
