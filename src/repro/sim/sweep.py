"""Batched sweep engine: run a `ScenarioSuite` across schemes, in parallel.

The unit of work is one `ScenarioCase`: every scheme runs against the same
scenario object, so per-case comparisons (speedups, CDFs) are paired. Work
items are independent and seeded by the suite, so results are identical
under serial, thread and process dispatch — the executor only changes
wall-clock, never output (apart from the wall-clock `planning_time`
measurements themselves).

Process dispatch uses the "spawn" start method by default: sweep workers
import only the numpy-based `repro.core` stack (never JAX), so interpreter
start-up is cheap and fork-safety issues with a JAX-initialized parent are
avoided.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import math
import multiprocessing
import os
import warnings
from collections.abc import Callable, Sequence

import numpy as np

from repro.core.simulator import SimResult, run_scheme
from repro.sim.suite import ScenarioCase, ScenarioSuite


# ------------------------------------------------------------------ records
@dataclasses.dataclass
class CaseResult:
    """All schemes' results for one scenario case."""

    index: int
    seed: int
    params: dict
    results: dict[str, SimResult]

    def time(self, scheme: str) -> float:
        return self.results[scheme].total_time


@dataclasses.dataclass(frozen=True)
class SchemeStats:
    """Distributional summary of one scheme over a sweep."""

    scheme: str
    count: int
    mean: float
    std: float
    p50: float
    p90: float
    min: float
    max: float
    mean_planning: float       # seconds of plan/optimize wall-clock per case
    planning_frac: float       # mean planning / (planning + simulated time)
    mean_rounds: float
    mean_relay_hops: float

    def __str__(self) -> str:
        return (
            f"{self.scheme}: n={self.count} mean={self.mean:.2f}s "
            f"std={self.std:.2f} p50={self.p50:.2f} p90={self.p90:.2f} "
            f"plan={self.mean_planning * 1e3:.2f}ms ({self.planning_frac * 100:.2f}%) "
            f"rounds={self.mean_rounds:.1f} relays={self.mean_relay_hops:.1f}"
        )


@dataclasses.dataclass(frozen=True)
class ByteVerification:
    """Outcome of `run_sweep(verify_bytes=...)`: a sampled subset of the
    sweep's cases re-planned and executed over *real bytes* (the batched
    data plane, `repro.core.engine.dataplane`) against stripes placed by
    `repro.ec.stripe` — every job's reconstructed block must equal the
    lost block bit-for-bit."""

    checked: tuple[tuple[int, str], ...]   # (case index, scheme) pairs
    failures: tuple[tuple[int, str], ...]
    nbytes: int                            # chunk size executed

    @property
    def verified(self) -> bool:
        return not self.failures


@dataclasses.dataclass
class SweepResult:
    """Structured output of `run_sweep`, with aggregation helpers."""

    suite: str
    schemes: tuple[str, ...]
    cases: list[CaseResult]
    byte_verification: ByteVerification | None = None

    def __len__(self) -> int:
        return len(self.cases)

    def _with(self, scheme: str) -> list[CaseResult]:
        return [c for c in self.cases if scheme in c.results]

    def times(self, scheme: str) -> np.ndarray:
        return np.array([c.results[scheme].total_time for c in self._with(scheme)])

    def stats(self, scheme: str) -> SchemeStats:
        sub = self._with(scheme)
        if not sub:
            raise KeyError(f"scheme {scheme!r} has no results in this sweep")
        t = np.array([c.results[scheme].total_time for c in sub])
        plan = np.array([c.results[scheme].planning_time for c in sub])
        rounds = np.array([c.results[scheme].num_rounds for c in sub])
        relays = np.array([c.results[scheme].relay_hops for c in sub])
        return SchemeStats(
            scheme=scheme, count=len(sub),
            mean=float(t.mean()), std=float(t.std()),
            p50=float(np.percentile(t, 50)), p90=float(np.percentile(t, 90)),
            min=float(t.min()), max=float(t.max()),
            mean_planning=float(plan.mean()),
            planning_frac=float((plan / (plan + t)).mean()),
            mean_rounds=float(rounds.mean()),
            mean_relay_hops=float(relays.mean()),
        )

    def summary(self) -> dict[str, SchemeStats]:
        return {s: self.stats(s) for s in self.schemes if self._with(s)}

    def speedups(self, baseline: str, scheme: str) -> np.ndarray:
        """Paired per-case ratios baseline_time / scheme_time (>1 = faster)."""
        pairs = [
            c for c in self.cases
            if baseline in c.results and scheme in c.results
        ]
        return np.array([
            c.results[baseline].total_time / c.results[scheme].total_time
            for c in pairs
        ])

    def speedup_cdf(self, baseline: str, scheme: str) -> tuple[np.ndarray, np.ndarray]:
        """(sorted speedups, empirical CDF) of `scheme` vs `baseline`."""
        s = np.sort(self.speedups(baseline, scheme))
        return s, np.arange(1, len(s) + 1) / len(s)

    def speedup_percentile(self, baseline: str, scheme: str, q: float) -> float:
        """The q-th percentile (0..100) of the paired speedup distribution,
        with the same interpolation convention as `SchemeStats` p50/p90."""
        return float(np.percentile(self.speedups(baseline, scheme), q))

    def reduction_pct(self, baseline: str, scheme: str) -> float:
        """Mean % repair-time reduction of `scheme` vs `baseline` (paper's
        headline metric): 100 * (1 - mean(scheme) / mean(baseline))."""
        pairs = [
            c for c in self.cases
            if baseline in c.results and scheme in c.results
        ]
        if not pairs:
            return float("nan")
        b = np.mean([c.results[baseline].total_time for c in pairs])
        s = np.mean([c.results[scheme].total_time for c in pairs])
        return float(100.0 * (1.0 - s / b))

    def filter(self, pred: Callable[[CaseResult], bool]) -> "SweepResult":
        return SweepResult(self.suite, self.schemes,
                           [c for c in self.cases if pred(c)])

    def group_by(self, *keys: str) -> dict[tuple, "SweepResult"]:
        """Split into sub-sweeps keyed by case-param values (grid axes)."""
        groups: dict[tuple, list[CaseResult]] = {}
        for c in self.cases:
            key = tuple(c.params.get(k) for k in keys)
            groups.setdefault(key, []).append(c)
        return {
            key: SweepResult(self.suite, self.schemes, sub)
            for key, sub in sorted(groups.items(), key=lambda kv: str(kv[0]))
        }

    def summary_table(self) -> str:
        return "\n".join(str(st) for st in self.summary().values())


# ------------------------------------------------------------------- engine
def _strip(r: SimResult) -> SimResult:
    """Drop the executed plan/log to keep cross-process results light."""
    return dataclasses.replace(r, plan=None, log=[])


def _run_case(
    case: ScenarioCase,
    schemes: tuple[str, ...],
    keep_plans: bool,
    bmf_optimize_all: bool,
) -> CaseResult:
    results: dict[str, SimResult] = {}
    for scheme in schemes:
        r = run_scheme(
            case.scenario, scheme,
            bmf_optimize_all=bmf_optimize_all, random_seed=case.seed,
        )
        results[scheme] = r if keep_plans else _strip(r)
    return CaseResult(
        index=case.index, seed=case.seed, params=dict(case.params),
        results=results,
    )


# Spawn amortization: a spawned worker must be fed at least this many
# cases to pay for its interpreter start-up + imports (~0.5 s each on this
# stack); below it a process pool is strictly slower than the serial loop
# (the regression BENCH_sweep.json documented: 0.04-0.37x serial on
# 60-case suites, where even a 3-worker pool loses 20x to its own spawns).
_MIN_CASES_PER_WORKER = 64


def _process_workers(num_items: int, max_workers: int | None) -> int:
    """Worker count for the process executor: never more than the spawn
    amortization threshold can feed. 0 means 'do not spawn — go serial'."""
    cap = max_workers or os.cpu_count() or 1
    return min(cap, num_items // _MIN_CASES_PER_WORKER)


# "auto" picks the jax executor only when it can amortize jit compile and
# per-round dispatch: a device backend (on CPU the tuned numpy engine is
# strictly faster — BENCH_sweep.json: jax lands *under* serial on sub-
# 100ms live suites), a trace-frozen suite (device epoch stacks are exact
# replays, no horizon-growth retries) and enough cases to fill batches.
_JAX_AUTO_MIN_CASES = 48


def _jax_pays_off(cases) -> bool:
    from repro.core.bandwidth import BandwidthTrace

    if len(cases) < _JAX_AUTO_MIN_CASES:
        return False
    if not all(type(c.scenario.bw) is BandwidthTrace for c in cases):
        return False
    try:
        from repro.core.engine import jax_available

        if not jax_available():
            return False
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - broken jax install
        return False


def _resolve_executor(executor: str, cases,
                      max_workers: int | None = None) -> str:
    """"auto" = the batched array engine: vectorized on CPU, jax when a
    device backend can amortize compilation (large trace-frozen suites).
    Both match the serial executor case for case, so auto never changes
    results — only wall-clock. The process pool stays opt-in: it only
    beats the vectorized engine for very long individual cases, which a
    heuristic cannot see."""
    if executor != "auto":
        return executor
    return "jax" if _jax_pays_off(cases) else "vectorized"


def run_sweep(
    suite: ScenarioSuite,
    *,
    schemes: Sequence[str] | None = None,
    executor: str = "auto",
    max_workers: int | None = None,
    keep_plans: bool = False,
    bmf_optimize_all: bool = False,
    mp_context: str = "spawn",
    verify_bytes: int | None = None,
) -> SweepResult:
    """Run every case of `suite` under every applicable scheme.

    `schemes` overrides both the suite default and per-case scheme sets;
    otherwise each case runs `case.schemes or suite.schemes`. Executors:
    "serial", "thread", "process" (object engine on a spawn pool; below
    the spawn-amortization threshold it warns and runs serial),
    "vectorized" (batched array engine — compatible cases step through
    `repro.core.engine` together), "jax" (the vectorized engine with
    jit-compiled device steppers from `repro.core.engine.jax_stepper`;
    falls back to the numpy steppers per batch when jax is missing or a
    batch is unsupported) or "auto" (the batched array engine: jax when
    a device backend can amortize compilation — large trace-frozen
    suites on an accelerator — else vectorized, the fastest CPU path).
    Output is independent of the executor choice.

    `verify_bytes=k` additionally byte-verifies `k` sampled cases: their
    plans are re-derived and executed over real bytes by the batched
    data plane against stripes placed by `repro.ec.stripe` (every
    scheme, PPT included via its store-and-forward lowering); the
    outcome lands in `SweepResult.byte_verification`. This turns a
    timing sweep into an end-to-end correctness probe of the whole
    planner + placement + GF(256) stack at a marginal cost.
    """
    cases = list(suite.cases())
    work = [
        (case, tuple(schemes) if schemes is not None
         else (case.schemes or tuple(suite.schemes)))
        for case in cases
    ]
    mode = _resolve_executor(executor, cases, max_workers)
    if mode == "process":
        workers = _process_workers(len(work), max_workers)
        if workers < 2:
            warnings.warn(
                f"process executor: {len(work)} cases cannot amortize "
                f"worker spawn cost (< {2 * _MIN_CASES_PER_WORKER} cases); "
                "falling back to serial",
                RuntimeWarning, stacklevel=2)
            mode = "serial"

    def jobs():
        for case, case_schemes in work:
            yield case, case_schemes, keep_plans, bmf_optimize_all

    if mode in ("vectorized", "jax"):
        results = _run_vectorized(
            work, keep_plans, bmf_optimize_all,
            backend="jax" if mode == "jax" else "numpy")
    elif mode == "serial":
        results = [_run_case(*args) for args in jobs()]
    elif mode == "thread":
        with concurrent.futures.ThreadPoolExecutor(max_workers=max_workers) as pool:
            results = list(pool.map(lambda args: _run_case(*args), jobs()))
    elif mode == "process":
        ctx = multiprocessing.get_context(mp_context)
        # few large tasks, not many tiny ones: each submitted task carries
        # a chunk of cases so per-task IPC/pickling is amortized too
        chunk = max(1, math.ceil(len(work) / (workers * 2)))
        with concurrent.futures.ProcessPoolExecutor(
                max_workers=workers, mp_context=ctx) as pool:
            results = list(pool.map(
                _run_case_star, jobs(), chunksize=chunk))
    else:
        raise ValueError(f"unknown executor {executor!r}")

    all_schemes: list[str] = []
    for _, case_schemes in work:
        for s in case_schemes:
            if s not in all_schemes:
                all_schemes.append(s)
    verification = None
    if verify_bytes:
        verification = _byte_verify(work, verify_bytes,
                                    bmf_optimize_all=bmf_optimize_all)
    return SweepResult(suite=suite.name, schemes=tuple(all_schemes),
                       cases=results, byte_verification=verification)


def _run_case_star(args) -> CaseResult:
    return _run_case(*args)


# ------------------------------------------------------------ byte verify
_VERIFY_NBYTES = 512


def _verify_plan(scenario, scheme: str, seed: int, bmf_optimize_all: bool):
    """The executed plan a (scenario, scheme) pair would produce — plans
    are pure functions of (scenario, scheme, seed), so re-deriving them
    here reproduces exactly what the sweep timed (including per-round BMF
    relay splices). PPT plans a pipeline tree, not rounds; its bytes are
    executed through the store-and-forward lowering `ppt_round_plan`."""
    from repro.core.ppt import build_ppt_tree, ppt_round_plan
    from repro.core.simulator import run_scheme

    if scheme == "ppt":
        tree = build_ppt_tree(scenario.make_jobs()[0],
                              scenario.bw.matrix_at(0.0))
        return ppt_round_plan(tree)
    return run_scheme(scenario, scheme, bmf_optimize_all=bmf_optimize_all,
                      random_seed=seed).plan


def _byte_verify(work, num_cases: int, *,
                 bmf_optimize_all: bool) -> ByteVerification:
    """Byte-verify a deterministic sample of the sweep's cases.

    Every sampled (case, scheme) pair gets its own stripe from
    `place_stripes` (RAID-5-style rotated placement over the case's
    failure domains), random payload bytes split by `split_blob`, and its
    plan relabeled through the placement — then the whole sample executes
    as ONE batched data-plane call. A failure here means some layer
    (planner, relabeling, placement, GF(256) math) corrupted bytes.
    """
    from repro.core.engine.arrays import compile_plan, relabel_plan_nodes
    from repro.core.engine.dataplane import execute_plans_batch
    from repro.ec.stripe import place_stripes, split_blob

    rng = np.random.default_rng(0x5712BE)
    picks = sorted(rng.choice(len(work), size=min(num_cases, len(work)),
                              replace=False).tolist())
    checked: list[tuple[int, str]] = []
    plans, codes, cws, bmaps = [], [], [], []
    for p in picks:
        case, case_schemes = work[p]
        sc = case.scenario
        code, cluster = sc.code, sc.num_nodes
        stripes = place_stripes(len(case_schemes), code, cluster)
        blob_rng = np.random.default_rng(case.seed)
        blob = blob_rng.integers(
            0, 256, size=len(case_schemes) * code.k * _VERIFY_NBYTES,
            dtype=np.uint8)
        datas = split_blob(blob, code.k, _VERIFY_NBYTES)
        for si, scheme in enumerate(case_schemes):
            plan = _verify_plan(sc, scheme, case.seed, bmf_optimize_all)
            stripe = stripes[si]
            pa = relabel_plan_nodes(compile_plan(plan), stripe.perm(cluster))
            checked.append((case.index, scheme))
            plans.append(pa)
            codes.append(code)
            cws.append(code.encode(datas[si]))
            bmaps.append(stripe.block_map(cluster))
    res = execute_plans_batch(plans, codes, cws, block_of=bmaps)
    failures = tuple(pair for pair, ok in zip(checked, res.verified)
                     if not ok)
    return ByteVerification(checked=tuple(checked), failures=failures,
                            nbytes=_VERIFY_NBYTES)


def _run_vectorized(
    work: list[tuple[ScenarioCase, tuple[str, ...]]],
    keep_plans: bool,
    bmf_optimize_all: bool,
    backend: str = "numpy",
) -> list[CaseResult]:
    """Dispatch work through the batched array engine, scheme by scheme.

    Cases sharing a scheme are handed to `run_scheme_vectorized`, which
    plans every case directly in `PlanArrays` space (true batched
    planning — each case owns its plan, no dedup/copy workarounds),
    groups them into structurally compatible batches (same cluster size
    and round count) and falls back to the object engine per case when a
    plan cannot be lowered to arrays. `backend="jax"` swaps the batch
    steppers for the jit-compiled device programs in
    `repro.core.engine.jax_stepper` (unsupported batches drop back to
    numpy). Results are identical to the serial executor (the engine
    parity tests pin this), only wall-clock changes.
    """
    from repro.core.engine.vectorized import run_work_vectorized

    flat: list[tuple[int, str]] = []
    rows = []
    for pos, (case, case_schemes) in enumerate(work):
        for s in case_schemes:
            flat.append((pos, s))
            rows.append((case.scenario, s, case.seed))

    by_pos: list[dict[str, SimResult]] = [{} for _ in work]
    sims = run_work_vectorized(rows, bmf_optimize_all=bmf_optimize_all,
                               keep_plans=keep_plans, backend=backend)
    for (pos, scheme), r in zip(flat, sims):
        by_pos[pos][scheme] = r if keep_plans else _strip(r)
    return [
        CaseResult(
            index=case.index, seed=case.seed, params=dict(case.params),
            results={s: by_pos[pos][s] for s in case_schemes},
        )
        for pos, (case, case_schemes) in enumerate(work)
    ]
