"""Scenario families: grid, Monte-Carlo and trace-replay suite generators.

A `ScenarioSuite` is an ordered, reproducible family of `ScenarioCase`s.
Every case carries its own derived seed (counter-based off the suite's
`base_seed`, so case i is identical no matter which subset of the suite is
generated or in which order the sweep engine runs it) plus the parameter
dict that produced it, which the result layer uses for grouping.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Callable, Iterator, Mapping, Sequence

import numpy as np

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel
from repro.core.simulator import MULTI_SCHEMES, Scenario
from repro.ec.rs import RSCode

# Named bandwidth-volatility regimes (kwargs for BandwidthProcess). The
# paper's measured settings: 5 s epoch for cold storage, 2 s for hot
# (Fig. 11), and the Aliyun WAN drift of Figs. 12/13 (fast, high-variance,
# correlated — markov sigma=1.0 rho=0.9 as in benchmarks.common).
VOLATILITY_REGIMES: dict[str, dict] = {
    "static": dict(change_interval=None),
    "cold5s": dict(change_interval=5.0, mode="markov"),
    "hot2s": dict(change_interval=2.0, mode="markov"),
    "jitter2s": dict(change_interval=2.0, mode="jitter", jitter=0.5),
    "redraw2s": dict(change_interval=2.0, mode="redraw"),
    "wan_drift": dict(change_interval=2.0, mode="markov", sigma=1.0, rho=0.9),
}

FAILURE_PATTERNS = ("single", "double", "rack")


def sample_failures(
    rng: np.random.Generator,
    n: int,
    k: int,
    pattern: str,
    *,
    rack_size: int = 4,
) -> tuple[int, ...]:
    """Sample a repairable failure set among codeword positions 0..n-1.

    * "single": one uniform node,
    * "double": two distinct uniform nodes (requires n - k >= 2),
    * "rack":   correlated, rack-aware — nodes are grouped into racks of
      `rack_size` consecutive ids; one rack fails up to min(2, n-k) of its
      members at once (the classic correlated-failure model: a ToR switch
      or PDU takes out co-located blocks together).
    """
    max_failures = n - k
    if max_failures < 1:
        raise ValueError(f"RS({n},{k}) cannot lose any node")
    if pattern == "single":
        return (int(rng.integers(n)),)
    if pattern == "double":
        if max_failures < 2:
            raise ValueError(f"RS({n},{k}) cannot lose two nodes")
        picks = rng.choice(n, size=2, replace=False)
        return tuple(sorted(int(x) for x in picks))
    if pattern == "rack":
        num_racks = (n + rack_size - 1) // rack_size
        rack = int(rng.integers(num_racks))
        members = list(range(rack * rack_size, min((rack + 1) * rack_size, n)))
        count = min(2, max_failures, len(members))
        picks = rng.choice(len(members), size=count, replace=False)
        return tuple(sorted(members[int(i)] for i in picks))
    raise ValueError(f"unknown failure pattern {pattern!r}")


@dataclasses.dataclass
class ScenarioCase:
    """One concrete scenario plus the metadata to reproduce/aggregate it."""

    suite: str
    index: int
    seed: int                               # per-case derived seed
    params: dict                            # generator parameters (grouping)
    scenario: Scenario
    schemes: tuple[str, ...] | None = None  # per-case override (else suite's)


class ScenarioSuite:
    """Base: an ordered, reproducible family of `ScenarioCase`s."""

    name: str = "suite"
    schemes: tuple[str, ...] = ("bmf",)

    def cases(self) -> Iterator[ScenarioCase]:
        raise NotImplementedError

    def __iter__(self) -> Iterator[ScenarioCase]:
        return self.cases()

    def __len__(self) -> int:
        raise NotImplementedError


def case_seed(base_seed: int, index: int) -> int:
    """Counter-based per-case seed: stable under subsetting/reordering."""
    return int(np.random.SeedSequence([base_seed, index]).generate_state(1)[0] & 0x7FFFFFFF)


# ------------------------------------------------------------------- grid
class GridSuite(ScenarioSuite):
    """Cartesian product of parameter axes x `trials` seeded repetitions.

    `build(params, seed)` receives one axis combination (plus "trial") and
    the trial's seed, and returns the `Scenario`. Trial t of every
    combination uses seed `base_seed + t` — matching the legacy
    `benchmarks.common.run_trials` convention so a grid sweep is
    bit-compatible with the old serial loops.
    """

    def __init__(
        self,
        name: str,
        axes: Mapping[str, Sequence],
        build: Callable[[dict, int], Scenario],
        *,
        trials: int = 1,
        schemes: Sequence[str] = ("bmf",),
        base_seed: int = 0,
    ):
        if trials < 1:
            raise ValueError("trials must be >= 1")
        self.name = name
        self.axes = {k: list(v) for k, v in axes.items()}
        self.build = build
        self.trials = trials
        self.schemes = tuple(schemes)
        self.base_seed = base_seed

    def combos(self) -> list[dict]:
        keys = list(self.axes)
        return [
            dict(zip(keys, vals))
            for vals in itertools.product(*(self.axes[k] for k in keys))
        ]

    def cases(self) -> Iterator[ScenarioCase]:
        index = 0
        for combo in self.combos():
            for trial in range(self.trials):
                seed = self.base_seed + trial
                params = dict(combo)
                params["trial"] = trial
                yield ScenarioCase(
                    suite=self.name, index=index, seed=seed, params=params,
                    scenario=self.build(dict(params), seed),
                )
                index += 1

    def __len__(self) -> int:
        combos = 1
        for vals in self.axes.values():
            combos *= len(vals)
        return combos * self.trials


# ------------------------------------------------------------ monte carlo
@dataclasses.dataclass(frozen=True)
class SampleSpace:
    """Distributions a `MonteCarloSuite` samples scenarios from."""

    codes: tuple[tuple[int, int], ...] = ((4, 2), (6, 3), (7, 4))
    cluster_sizes: tuple[int, ...] = (10, 14)
    chunk_mb: tuple[float, ...] = (8.0, 16.0, 32.0)
    regimes: tuple[str, ...] = ("cold5s", "hot2s", "wan_drift")
    failure_patterns: tuple[str, ...] = ("single",)
    bw_low: float = 3.0
    bw_high: float = 30.0
    rack_size: int = 4
    ingress_degrade: float = 0.10
    ingress_floor: float = 0.40
    ingress_alpha: float = 1.0
    ingress_duplex: float = 0.65

    def __post_init__(self):
        for n, k in self.codes:
            if not 0 < k < n:
                raise ValueError(f"invalid code ({n},{k})")
        for r in self.regimes:
            if r not in VOLATILITY_REGIMES:
                raise ValueError(f"unknown regime {r!r} (have {list(VOLATILITY_REGIMES)})")
        for p in self.failure_patterns:
            if p not in FAILURE_PATTERNS:
                raise ValueError(f"unknown failure pattern {p!r}")
        if self.bw_low <= 0 or self.bw_high < self.bw_low:
            raise ValueError("need 0 < bw_low <= bw_high")


class MonteCarloSuite(ScenarioSuite):
    """`num_cases` scenarios sampled i.i.d. from a `SampleSpace`.

    Case i's draws come from `SeedSequence([base_seed, i])`, so the suite
    is fully reproducible, and any case can be regenerated in isolation.
    When `schemes` is None, each case gets the scheme set matching its
    failure cardinality: single-failure cases compare
    traditional/ppr/ppt/bmf, multi-failure cases mppr/random/msrepair —
    one sweep can therefore span both of the paper's evaluation families.
    """

    def __init__(
        self,
        name: str,
        num_cases: int,
        space: SampleSpace | None = None,
        *,
        schemes: Sequence[str] | None = None,
        base_seed: int = 0,
    ):
        if num_cases < 1:
            raise ValueError("num_cases must be >= 1")
        self.name = name
        self.num_cases = num_cases
        self.space = space or SampleSpace()
        self.schemes = tuple(schemes) if schemes is not None else None
        self.base_seed = base_seed

    def _make_case(self, i: int) -> ScenarioCase:
        sp = self.space
        rng = np.random.default_rng(np.random.SeedSequence([self.base_seed, i]))
        seed = case_seed(self.base_seed, i)
        n, k = sp.codes[int(rng.integers(len(sp.codes)))]
        fits = [c for c in sp.cluster_sizes if c >= n] or [max(max(sp.cluster_sizes), n)]
        cluster = int(fits[int(rng.integers(len(fits)))])
        chunk = float(sp.chunk_mb[int(rng.integers(len(sp.chunk_mb)))])
        regime = sp.regimes[int(rng.integers(len(sp.regimes)))]
        feasible = [
            p for p in sp.failure_patterns
            if not (p == "double" and n - k < 2)
        ]
        pattern = feasible[int(rng.integers(len(feasible)))]
        failed = sample_failures(rng, n, k, pattern, rack_size=sp.rack_size)
        base = topology.heterogeneous_matrix(
            cluster, low=sp.bw_low, high=sp.bw_high, seed=seed)
        bwp = BandwidthProcess(base=base, seed=seed, **VOLATILITY_REGIMES[regime])
        ingress = IngressModel(
            seed=seed, degrade=sp.ingress_degrade, floor=sp.ingress_floor,
            alpha=sp.ingress_alpha, duplex=sp.ingress_duplex)
        scenario = Scenario(
            num_nodes=cluster, code=RSCode(n, k), failed=failed,
            bw=bwp, ingress=ingress, chunk_mb=chunk)
        if self.schemes is not None:
            schemes = None  # suite-level set applies
        elif len(failed) > 1:
            schemes = MULTI_SCHEMES
        else:
            schemes = ("traditional", "ppr", "ppt", "bmf")
        params = dict(code=(n, k), cluster=cluster, chunk_mb=chunk,
                      regime=regime, pattern=pattern, failed=failed)
        return ScenarioCase(
            suite=self.name, index=i, seed=seed, params=params,
            scenario=scenario, schemes=schemes,
        )

    def cases(self) -> Iterator[ScenarioCase]:
        for i in range(self.num_cases):
            yield self._make_case(i)

    def __len__(self) -> int:
        return self.num_cases


# ------------------------------------------------------------ trace replay
class TraceSuite(ScenarioSuite):
    """A suite whose bandwidth processes are recorded `BandwidthTrace`s.

    `freeze()` snapshots every case of another suite: each scenario's
    synthetic bandwidth process is recorded for `num_epochs` epochs and
    replaced by its replay, so *every* scheme — and every future planner
    variant — sees the exact same sample path, epoch for epoch. This is
    the apples-to-apples mode for A/B-ing planner changes.
    """

    def __init__(
        self,
        name: str,
        cases: Sequence[ScenarioCase],
        *,
        schemes: Sequence[str] = ("bmf",),
    ):
        self.name = name
        self._cases = list(cases)
        self.schemes = tuple(schemes)

    @classmethod
    def freeze(
        cls,
        suite: ScenarioSuite,
        *,
        num_epochs: int = 64,
        name: str | None = None,
    ) -> "TraceSuite":
        frozen: list[ScenarioCase] = []
        for case in suite.cases():
            bw = case.scenario.bw
            if isinstance(bw, BandwidthProcess):
                bw = BandwidthTrace.record(bw, num_epochs)
            sc = dataclasses.replace(case.scenario, bw=bw)
            frozen.append(dataclasses.replace(
                case, suite=name or f"{suite.name}@trace", scenario=sc))
        out = cls(name or f"{suite.name}@trace", frozen,
                  schemes=suite.schemes or ("bmf",))
        return out

    def cases(self) -> Iterator[ScenarioCase]:
        return iter(self._cases)

    def __len__(self) -> int:
        return len(self._cases)
