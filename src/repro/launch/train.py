"""End-to-end training driver with EC-checkpointed fault tolerance.

On real hardware this runs under `python -m repro.launch.train --arch <id>`
across hosts; on this CPU container it drives reduced configs end-to-end —
the same code path the FT tests and examples/quickstart.py exercise:

  loop:  data -> train_step -> metrics
         every --ckpt-every steps: async EC-checkpoint save
         failure injected?  -> repair checkpoint shards (BMFRepair/MSRepair)
                            -> elastic re-mesh (shrink data axis)
                            -> resume from latest step
         straggler flagged? -> evict host via the same elastic path
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ECCheckpointConfig, ECCheckpointer
from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeConfig
from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.data.pipeline import SyntheticStream
from repro.ft import FailureInjector, StragglerMonitor
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (CPU container)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a 2-domain failure at this step")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", "train", args.seq_len, args.batch)
    tcfg = TrainConfig(
        adamw=AdamWConfig(peak_lr=args.lr, warmup_steps=10),
        microbatches=args.microbatches,
        attn_chunk=min(1024, args.seq_len),
        compress_grads=args.compress_grads,
    )

    # EC checkpointing over a simulated 8-domain host network
    _, bwm = topology.tpu_pod_dcn_matrix(8, 1, seed=args.seed)
    ck = ECCheckpointer(
        ECCheckpointConfig(directory=args.ckpt_dir, n=6, k=4,
                           chunk_bytes=1 << 18, num_domains=8),
        bw=BandwidthProcess(base=bwm, change_interval=2.0, mode="markov",
                            seed=args.seed),
        ingress=IngressModel(seed=args.seed),
    )
    injector = FailureInjector(
        num_domains=8,
        scheduled=(() if args.fail_at < 0 else
                   (__import__("repro.ft.failures", fromlist=["FailureEvent"])
                    .FailureEvent(step=args.fail_at, domains=(1, 5)),)),
    )
    monitor = StragglerMonitor(num_hosts=8)

    state = init_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
    start = 0
    if args.resume and ck.latest_step() is not None:
        state, report = ck.load(state)
        start = int(np.asarray(state["step"]))
        print(f"[train] resumed from step {start} "
              f"(repaired {report.blocks_repaired} blocks)")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    stream = SyntheticStream(cfg, shape)

    for step in range(start, args.steps):
        ev = injector.check(step)
        if ev is not None:
            print(f"[train] FAILURE at step {step}: domains {ev.domains} — "
                  f"repairing checkpoint + elastic restart")
            ck.wait()
            state, report = ck.load(state, lost_domains=ev.domains)
            sim_t = None if report.sim is None else report.sim.total_time
            print(f"[train] repaired {report.blocks_repaired} blocks "
                  f"({report.stripes_repaired} stripes), scheme sim time "
                  f"{sim_t}, wall {report.wall_seconds:.2f}s")
            step = int(np.asarray(state["step"]))
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        monitor.record(step % 8, dt)       # simulated per-host step times
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step}: loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} ({dt:.2f}s)")
        if step > 0 and step % args.ckpt_every == 0:
            ck.save(step, state)
        if monitor.stragglers():
            print(f"[train] stragglers flagged: {monitor.stragglers()}")
    ck.save(args.steps, state, wait=True)
    print("[train] done")


if __name__ == "__main__":
    main()
