"""Per-(arch x shape) execution plans: microbatching, chunk sizes, remat.

These keep every dry-run cell inside a v5e chip's 16 GiB HBM (verified by
compiled.memory_analysis()); they do not change step semantics or total
FLOPs, only scheduling.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig

# microbatches for train_4k (global_batch=256)
TRAIN_MICROBATCHES = {
    "grok1_314b": 8,
    "moonlight_16b_a3b": 4,
    "zamba2_7b": 8,
    "gemma3_4b": 4,
    "gemma_2b": 2,
    "qwen2_15b": 2,
    "qwen2vl_2b": 2,
    "whisper_medium": 2,
    "rwkv6_16b": 2,
    "smollm_360m": 4,
}

DECODE_CHUNK = {"decode_32k": 4096, "long_500k": 8192}


# int8 KV cache: halves the bf16 caches that overflow a single pod
# (grok-1 1.1 TB, moonlight 3.3 TB global at decode_32k). Window-sliced
# archs (gemma3) keep bf16 (their cache win comes from slicing).
INT8_KV = {"grok1_314b", "moonlight_16b_a3b"}


@dataclasses.dataclass(frozen=True)
class CellPlan:
    train: TrainConfig | None = None
    attn_chunk: int = 1024
    decode_chunk: int = 4096
    kv_dtype: str = "bf16"


def plan_for(cfg: ArchConfig, shape: ShapeConfig) -> CellPlan:
    if shape.kind == "train":
        tcfg = TrainConfig(
            adamw=AdamWConfig(),
            microbatches=TRAIN_MICROBATCHES.get(cfg.name, 2),
            remat=True,
            attn_chunk=1024,
            # grok-314B: f32 m/v alone is 2.5 TB; bf16 halves optimizer
            # HBM so the single-pod (256 x 16 GiB) mesh fits
            opt_dtype="bfloat16" if cfg.name == "grok1_314b" else "float32",
        )
        return CellPlan(train=tcfg)
    if shape.kind == "prefill":
        return CellPlan(attn_chunk=1024)
    return CellPlan(
        decode_chunk=DECODE_CHUNK.get(shape.name, 4096),
        kv_dtype="int8" if (cfg.name in INT8_KV
                            and shape.name == "decode_32k") else "bf16",
    )
