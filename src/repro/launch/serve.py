"""Batched serving driver: prefill a batch of prompts, decode N tokens."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.serve.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_15b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = __import__("repro.models.model", fromlist=["init_params"]
                        ).init_params(key, cfg)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model))
    if cfg.mrope:
        t = args.prompt_len
        pos = jnp.broadcast_to(jnp.arange(t)[None], (args.batch, t))
        batch["pos3"] = jnp.broadcast_to(pos[None], (3, args.batch, t)
                                         ).astype(jnp.int32)

    t0 = time.time()
    out = generate(params, cfg, batch, steps=args.gen_tokens,
                   temperature=args.temperature, key=key,
                   chunk=min(1024, args.prompt_len))
    dt = time.time() - t0
    toks = args.batch * args.gen_tokens
    print(f"[serve] arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")
    print("[serve] sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
