"""Post-SPMD HLO cost analyzer with correct loop accounting.

XLA's built-in cost_analysis() counts each while-loop body ONCE — under
scan-over-layers + microbatch scans (this framework's bread and butter)
it underestimates FLOPs/bytes/collectives by the trip-count product
(verified empirically: 4x microbatches -> 4x lower reported flops). This
module parses `compiled.as_text()` and walks the call graph multiplying
while bodies by their trip counts.

Accounting rules:
  * flops: `dot` ops only (2 * result_elems * contraction_size) — matmuls
    dominate every cell; elementwise flops are noise in comparison.
  * bytes: operand + result bytes of top-level ops that touch HBM
    (fusion, dot, copy, slice/update ops, collectives, reduce, sort,
    gather/scatter). Ops *inside* fusion computations are skipped (fused
    intermediates never round-trip HBM). An estimate, but a consistent one.
  * collectives: operand bytes of all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute (+ their async -start forms), times the
    enclosing loops' trip counts.
  * while trip count: max integer literal in the loop's condition
    computation (jax scans lower to `iv < N` conditions).

All numbers are per device (the partitioned module is the per-device
program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# NOTE: `parameter` is deliberately NOT counted: while-body parameters are
# whole carry tuples (the entire train state) and would overcount HBM
# traffic by orders of magnitude; real weight reads surface as
# dynamic-slice / fusion operands instead.
_BYTES_OPS = (
    "fusion", "dot", "copy", "dynamic-slice", "dynamic-update-slice",
    "slice", "reduce", "sort", "gather", "scatter", "transpose",
    "concatenate", "pad", "broadcast", "iota", "convert", "select",
) + COLLECTIVE_KINDS

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_WHILE_COND = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _comp_header(line: str) -> tuple[str, bool] | None:
    """Computation header: 'name (params...) -> type {' (params may nest).
    Returns (name, is_entry)."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s or " = " in s:
        return None
    m = _COMP_HEADER.match(s)
    if not m:
        return None
    return m.group(1), s.startswith("ENTRY")


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


@dataclasses.dataclass
class OpInfo:
    name: str
    kind: str
    result_bytes: int
    result_elems: int
    operand_names: list
    operand_bytes: int          # resolved after the computation is parsed
    flops: float
    collective_kind: str | None
    called: list                # computation names (fused, to_apply, ...)
    is_while: bool
    cond_name: str | None = None
    body_name: str | None = None
    result_dims: list = dataclasses.field(default_factory=list)
    lhs_contracting: list = dataclasses.field(default_factory=list)
    max_operand_bytes: int = 0


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_fused: bool = False      # target of a fusion op


def _parse_op(line: str) -> OpInfo | None:
    m = _OP_RE.match(line)
    if not m or "{" in line.split("=")[0]:
        return None
    name, rhs = m.groups()
    # result type: leading tuple "(...)" or single "dtype[dims]{layout}"
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_part = rhs[: i + 1]
        rest = rhs[i + 1:]
    else:
        sp = rhs.find(" ")
        type_part = rhs[:sp] if sp > 0 else rhs
        rest = rhs[sp + 1:] if sp > 0 else ""
    elems, rbytes = _shape_elems_bytes(type_part)
    # op kind = first token of rest up to "("
    km = re.match(r"\s*([a-z][\w\-]*)", rest)
    kind = km.group(1) if km else "?"
    # operands: inside the eventual first (...) group
    ops_names: list[str] = []
    pm = re.search(r"\(([^)]*)\)", rest)
    if pm:
        for tok in pm.group(1).split(","):
            tok = tok.strip()
            mm = re.search(r"%([\w.\-]+)\s*$", tok)
            if mm:
                ops_names.append(mm.group(1))
    called: list[str] = []
    for cm in _CALL_ATTR.finditer(rest):
        for c in cm.group(1).split(","):
            called.append(c.strip().lstrip("%"))
    cond_name = body_name = None
    if kind == "while":
        cm = _WHILE_COND.search(rest)
        bm = _WHILE_BODY.search(rest)
        cond_name = cm.group(1) if cm else None
        body_name = bm.group(1) if bm else None
    coll = None
    for ck in COLLECTIVE_KINDS:
        if kind == ck or kind == ck + "-start":
            coll = ck
            break
    flops = 0.0
    lhs_contracting: list[int] = []
    if kind == "dot":
        lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
        if lm and lm.group(1):
            lhs_contracting = [int(x) for x in lm.group(1).split(",")]
    result_dims = []
    for dtype, dims in _SHAPE_RE.findall(type_part):
        result_dims.append([int(d) for d in dims.split(",")] if dims else [])
    return OpInfo(
        name=name, kind=kind, result_bytes=rbytes, result_elems=elems,
        operand_names=ops_names, operand_bytes=0, flops=flops,
        collective_kind=coll, called=called,
        is_while=(kind == "while"),
        cond_name=cond_name, body_name=body_name,
        result_dims=result_dims, lhs_contracting=lhs_contracting,
    )


def parse_module(hlo_text: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        if cur is None:
            hdr = _comp_header(line)
            if hdr:
                cur = Computation(name=hdr[0], ops=[])
                if hdr[1]:
                    entry = hdr[0]
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op(line)
        if op:
            cur.ops.append(op)
    # resolve operand bytes + dot flops within each computation
    for comp in comps.values():
        sizes = {op.name: op.result_bytes for op in comp.ops}
        dims = {op.name: op.result_dims for op in comp.ops}
        for op in comp.ops:
            op.operand_bytes = sum(sizes.get(n, 0) for n in op.operand_names)
            op.max_operand_bytes = max(
                (sizes.get(n, 0) for n in op.operand_names), default=0)
            if op.kind == "dot" and op.operand_names:
                lhs_dims_list = dims.get(op.operand_names[0], [])
                lhs_dims = lhs_dims_list[0] if lhs_dims_list else []
                csize = 1
                for ci in op.lhs_contracting:
                    if ci < len(lhs_dims):
                        csize *= lhs_dims[ci]
                op.flops = 2.0 * op.result_elems * csize
    # mark fusion targets
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                for c in op.called:
                    if c in comps:
                        comps[c].is_fused = True
    return comps, entry


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        for m in _CONST_INT.finditer(
                " ".join([op.kind] + [str(op.operand_names)])):
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + v * mult)
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (
                self.collective_counts.get(k, 0.0) + v * mult)


def _dus_update_bytes(comp: "Computation", op: "OpInfo") -> int:
    """Bytes of the update operand (operand[1]) of a dynamic-update-slice;
    falls back to result bytes when unresolvable."""
    sizes = {o.name: o.result_bytes for o in comp.ops}
    if len(op.operand_names) >= 2 and op.operand_names[1] in sizes:
        return sizes[op.operand_names[1]]
    return op.result_bytes


def analyze(hlo_text: str) -> dict:
    comps, entry_name = parse_module(hlo_text)
    # trip counts need raw condition text (constants live in op lines we
    # already parsed; constants appear as `constant(N)` in the rhs, which
    # _parse_op folded into kind/operands — re-scan text per computation)
    cond_trips: dict[str, int] = {}
    cur_name, cur_best = None, 1
    for line in hlo_text.splitlines():
        if cur_name is None:
            hdr = _comp_header(line)
            if hdr:
                cur_name, cur_best = hdr[0], 1
            continue
        if line.strip() == "}":
            cond_trips[cur_name] = cur_best
            cur_name = None
            continue
        for m in _CONST_INT.finditer(line):
            cur_best = max(cur_best, int(m.group(1)))

    memo: dict[str, Totals] = {}

    def total_of(name: str, for_bytes: bool) -> Totals:
        key = name + ("#b" if for_bytes else "#f")
        if key in memo:
            return memo[key]
        t = Totals()
        comp = comps.get(name)
        if comp is None:
            memo[key] = t
            return t
        for op in comp.ops:
            t.flops += op.flops
            is_dus = (op.kind == "dynamic-update-slice"
                      or (op.kind == "fusion"
                          and "dynamic-update-slice" in op.name))
            if is_dus:
                # in-place read-modify-write: XLA aliases the big buffer
                # (plain DUS and DUS-rooted fusions); charging
                # operand+result would bill a full KV-cache rewrite per
                # layer per step (~500 GB/dev of phantom traffic measured
                # on long-context decode). Count the non-buffer operands
                # (the update + indices) read + written.
                t.bytes += 2 * max(op.operand_bytes - op.max_operand_bytes, 0)
            elif op.kind == "dynamic-slice":
                # reads only the slice: result bytes (+index scalars)
                t.bytes += 2 * op.result_bytes
            elif op.kind in _BYTES_OPS:
                t.bytes += op.operand_bytes + op.result_bytes
            if op.collective_kind:
                t.collective_bytes += op.operand_bytes
                t.collective_by_kind[op.collective_kind] = (
                    t.collective_by_kind.get(op.collective_kind, 0.0)
                    + op.operand_bytes)
                t.collective_counts[op.collective_kind] = (
                    t.collective_counts.get(op.collective_kind, 0.0) + 1)
            if op.is_while:
                trips = cond_trips.get(op.cond_name, 1)
                for c in (op.cond_name, op.body_name):
                    if c:
                        t.add(total_of(c, for_bytes), trips)
            elif op.kind == "fusion":
                for c in op.called:
                    sub = total_of(c, for_bytes)
                    # fused internals: flops yes, HBM bytes no
                    t.flops += sub.flops
                    t.collective_bytes += sub.collective_bytes
            elif op.called and op.kind in ("call", "conditional",
                                           "async-start"):
                for c in op.called:
                    if comps.get(c) and not comps[c].is_fused:
                        t.add(total_of(c, for_bytes), 1.0)
            # reduce/sort to_apply bodies: scalar math, negligible
        memo[key] = t
        return t

    entry = entry_name
    if entry is None:            # fallback: the computation nobody calls
        called_by: set[str] = set()
        for comp in comps.values():
            for op in comp.ops:
                called_by.update(op.called + [op.cond_name, op.body_name])
        for name in comps:
            if name not in called_by:
                entry = name
                break
    t = total_of(entry, True)
    return {
        "entry": entry,
        "flops_per_device": t.flops,
        "bytes_per_device": t.bytes,
        "collective_bytes_per_device": t.collective_bytes,
        "collective_by_kind": t.collective_by_kind,
        "collective_counts": t.collective_counts,
    }
