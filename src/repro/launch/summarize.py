"""Summarize dry-run results into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.summarize results/dryrun [--md]
"""
from __future__ import annotations

import glob
import json
import sys


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load(dirname):
    rows = [json.load(open(f)) for f in sorted(glob.glob(f"{dirname}/*.json"))]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return rows


def dryrun_table(rows):
    print("| arch | shape | mesh | chips | fits (GiB/chip) | HLO GFLOPs/dev | "
          "HBM GB/dev | coll GB/dev (top kind) | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        h = r["hlo_analysis"]
        coll = h["collective_by_kind"]
        top = max(coll, key=coll.get) if coll else "-"
        gib = r.get("per_device_bytes", 0) / 2**30
        outs = r["memory_analysis"].get("output_size_in_bytes", 0) / 2**30
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
              f"| {gib:.1f}(+{outs:.1f} out) "
              f"| {h['flops_per_device'] / 1e9:.1f} "
              f"| {h['bytes_per_device'] / 1e9:.1f} "
              f"| {h['collective_bytes_per_device'] / 1e9:.2f} ({top}) "
              f"| {r['compile_s']:.0f} |")


def roofline_table(rows, mesh="single"):
    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL_FLOPS | useful ratio | limiter note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        u = r.get("useful_compute_ratio")
        dom = rf["dominant"].replace("_s", "")
        note = {
            "memory": "HBM traffic (attn score streams / cache reads)",
            "compute": "MXU matmuls",
            "collective": "ICI collectives",
        }[dom]
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3f} "
              f"| {rf['memory_s']:.3f} | {rf['collective_s']:.3f} | {dom} "
              f"| {r['model_flops_global']:.2e} "
              f"| {u if u is None else f'{u:.3f}'} | {note} |")


def main():
    dirname = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = load(dirname)
    print(f"## Dry-run: {len(rows)} cells\n")
    dryrun_table(rows)
    print("\n## Roofline (single-pod 16x16, 256 chips)\n")
    roofline_table(rows, "single")
    print("\n## Roofline (multi-pod 2x16x16, 512 chips)\n")
    roofline_table(rows, "multi")


if __name__ == "__main__":
    main()
