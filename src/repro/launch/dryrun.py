import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). 512 host devices back the 2x16x16 production mesh.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import SHAPES, ARCH_IDS, applicable_shapes, get_arch  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.cells import plan_for  # noqa: E402
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import whisper as W  # noqa: E402
from repro.models.sharding import tree_shardings  # noqa: E402
from repro.serve import serve_step as S  # noqa: E402
from repro.train import train_step as T  # noqa: E402

# v5e hardware constants for the roofline (see EXPERIMENTS.md section Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples by summing elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the (per-device,
    post-SPMD-partitioning) HLO. Returns per-device byte counts by kind.

    `-start` variants (async collectives) are counted; their `-done` halves
    carry no new payload and are skipped.
    """
    sizes: dict[str, int] = {}
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    coll_re = re.compile(
        r"\b(" + "|".join(_COLLECTIVES) + r")(-start)?\(([^)]*)\)")
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # result type prefix: "f32[1,2]{1,0} op(...)" or "(f32[..], ...) op(...)"
        if rhs.startswith("("):
            end = rhs.find(") ")
            type_part = rhs[: end + 1] if end >= 0 else rhs
        else:
            type_part = rhs.split(" ", 1)[0]
        sizes[name.lstrip("%")] = _type_bytes(type_part)
        mm = coll_re.search(rhs)
        if mm and "-done" not in rhs.split("(")[0]:
            kind = mm.group(1)
            ops = [o.strip().lstrip("%")
                   for o in mm.group(3).split(",") if o.strip()]
            nbytes = sum(sizes.get(o, 0) for o in ops)
            out[kind] += nbytes
            counts[kind] += 1
    return {"bytes_per_device": out, "counts": counts,
            "total_bytes_per_device": sum(out.values())}


def count_params(struct_tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(struct_tree))


def active_params(cfg, params_struct) -> int:
    total = count_params(params_struct)
    if cfg.moe is None:
        return total
    # expert weights activate top_k / num_experts
    expert = 0
    flat = jax.tree.flatten_with_path(params_struct)[0]
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "moe" in keys and any(k in ("wi_gate", "wi_up", "wo") for k in keys):
            expert += int(np.prod(leaf.shape))
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - expert + int(expert * frac)


def model_flops(cfg, shape, n_active: int) -> float:
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.is_encoder_decoder:
            t = t + min(cfg.max_decoder_len, t)
        return 6.0 * n_active * b * t
    if shape.kind == "prefill":
        return 2.0 * n_active * b * t
    return 2.0 * n_active * b            # decode: one token per sequence


# --------------------------------------------------------------- cell build
def build_lowered(arch_id: str, shape_name: str, mesh_kind: str):
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = rules_for(mesh)
    plan = plan_for(cfg, shape)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        tcfg = plan.train
        state_struct = jax.eval_shape(lambda: T.init_state(key, cfg, tcfg))
        state_sh = tree_shardings(
            rules, state_struct, T.state_logical(cfg, tcfg, rules))
        batch_struct = M.input_specs(cfg, shape)
        batch_sh = tree_shardings(
            rules, batch_struct, M.batch_logical(cfg, shape))
        step = T.make_train_step(cfg, tcfg, rules)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_struct, batch_struct)
        return lowered, cfg, shape, state_struct["params"]

    params_struct = jax.eval_shape(lambda: M.init_params(key, cfg))
    params_sh = tree_shardings(
        rules, params_struct,
        M.logical_params(cfg, rules, decode=(shape.kind == "decode")))

    if shape.kind == "prefill":
        batch_struct = M.input_specs(cfg, shape)
        batch_sh = tree_shardings(
            rules, batch_struct, M.batch_logical(cfg, shape))
        prefill = S.make_prefill(cfg, rules, chunk=plan.attn_chunk,
                                 max_len=shape.seq_len)
        jitted = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
        with mesh:
            lowered = jitted.lower(params_struct, batch_struct)
        return lowered, cfg, shape, params_struct

    # decode
    b, s = shape.global_batch, shape.seq_len
    batch_struct = M.input_specs(cfg, shape)
    batch_sh = tree_shardings(
        rules, batch_struct, M.batch_logical(cfg, shape))
    if cfg.is_encoder_decoder:
        kv, hd = cfg.num_kv_heads, cfg.hd
        cache_struct = {
            "self": jax.eval_shape(
                lambda: W.init_self_cache(cfg, b, cfg.max_decoder_len, rules)),
            "xk": jax.ShapeDtypeStruct(
                (cfg.num_layers, b, s, kv, hd), jnp.bfloat16),
            "xv": jax.ShapeDtypeStruct(
                (cfg.num_layers, b, s, kv, hd), jnp.bfloat16),
        }
        cache_logical = {
            "self": {"k": (None, "batch", None, "tp", None),
                     "v": (None, "batch", None, "tp", None),
                     "pos": ("batch", None), "idx": ()},
            "xk": (None, "batch", None, "tp", None),
            "xv": (None, "batch", None, "tp", None),
        }
        step_fn = S.make_whisper_decode_step(cfg, rules, plan.decode_chunk)

        def decode(params, token, cache):
            return step_fn(params, token, cache)
    else:
        cache_struct = jax.eval_shape(
            lambda: M.init_cache(cfg, b, s, rules, kv_dtype=plan.kv_dtype))
        cache_logical = M.cache_logical(cfg, rules, kv_dtype=plan.kv_dtype)
        step_fn = S.make_decode_step(cfg, rules, plan.decode_chunk)

        def decode(params, token, cache, pos3=None):
            return step_fn(params, token, cache, pos3)

    cache_sh = tree_shardings(rules, cache_struct, cache_logical)
    args = [params_struct, batch_struct["token"], cache_struct]
    in_sh = [params_sh, batch_sh["token"], cache_sh]
    if cfg.mrope:
        args.append(batch_struct["pos3"])
        in_sh.append(batch_sh["pos3"])
    # serving loops donate the cache: the updated cache aliases the input
    # buffers instead of doubling the footprint
    jitted = jax.jit(decode, in_shardings=tuple(in_sh), donate_argnums=(2,))
    with mesh:
        lowered = jitted.lower(*args)
    return lowered, cfg, shape, params_struct


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             out_dir: str, *, skip_existing: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_kind}.json")
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)
    t0 = time.time()
    lowered, cfg, shape, params_struct = build_lowered(
        arch_id, shape_name, mesh_kind)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    t0 = time.time()
    analysis = hlo_analysis.analyze(hlo)
    t_analyze = time.time() - t0

    chips = 512 if mesh_kind == "multi" else 256
    n_total = count_params(params_struct)
    n_active = active_params(cfg, params_struct)
    # XLA's cost_analysis counts while bodies ONCE (scan-underreporting);
    # hlo_analysis re-walks the module with trip-count multiplication.
    flops_pd = float(analysis["flops_per_device"])
    bytes_pd = float(analysis["bytes_per_device"])
    coll_pd = float(analysis["collective_bytes_per_device"])

    compute_s = flops_pd / PEAK_FLOPS
    memory_s = bytes_pd / HBM_BW
    collective_s = coll_pd / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mflops = model_flops(cfg, shape, n_active)

    record = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "chips": chips,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        },
        "cost_analysis_xla_raw": {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")},
        "hlo_analysis": {
            "flops_per_device": flops_pd,
            "bytes_per_device": bytes_pd,
            "collective_bytes_per_device": coll_pd,
            "collective_by_kind": analysis["collective_by_kind"],
            "collective_counts": analysis["collective_counts"],
            "analyze_s": round(t_analyze, 1),
        },
        "params_total": n_total,
        "params_active": n_active,
        "model_flops_global": mflops,
        "hlo_flops_global": flops_pd * chips,
        "useful_compute_ratio": (mflops / (flops_pd * chips)
                                 if flops_pd else None),
        "roofline": {
            **terms,
            "dominant": dominant,
        },
    }
    # per-device HBM residency (arguments+temp) — the fits-in-16GiB check
    ma = record["memory_analysis"]
    if ma:
        record["per_device_bytes"] = (
            ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0))
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] {arch_id} x {shape_name} x {mesh_kind}: OK "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s, "
          f"dominant={dominant}, per-dev "
          f"{record.get('per_device_bytes', 0)/2**30:.2f} GiB)")
    print("  memory_analysis:", record["memory_analysis"])
    print("  cost_analysis(flops)=%.3e bytes=%.3e coll=%.3e"
          % (flops_pd, bytes_pd, coll_pd))
    return record


def all_cells() -> list[tuple[str, str, str]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_arch(arch)
        for shape in applicable_shapes(cfg):
            for mesh in ("single", "multi"):
                cells.append((arch, shape, mesh))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print(*c)
        return
    if args.all:
        failures = []
        for arch, shape, mesh in all_cells():
            out_path = os.path.join(
                args.out, f"{arch}__{shape}__{mesh}.json")
            if os.path.exists(out_path) and not args.force:
                print(f"[dryrun] skip cached {arch} x {shape} x {mesh}")
                continue
            # fresh subprocess per cell: clean device state, bounded memory
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", mesh,
                 "--out", args.out],
                env={**os.environ, "PYTHONPATH": "src"},
            )
            if r.returncode != 0:
                failures.append((arch, shape, mesh))
        if failures:
            print("FAILED cells:", failures)
            sys.exit(1)
        print("all cells OK")
        return
    run_cell(args.arch, args.shape, args.mesh, args.out,
             skip_existing=not args.force)


if __name__ == "__main__":
    main()
