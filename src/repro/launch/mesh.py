"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state — the dry-run driver must set XLA_FLAGS before
the first jax call.
"""
from __future__ import annotations

import jax

from repro.models.sharding import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def rules_for(mesh) -> MeshRules:
    """FSDP over (pod,)data; tensor over model."""
    fsdp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return MeshRules(mesh=mesh, fsdp=fsdp, tensor="model")


def make_test_mesh(*, multi_pod: bool = False, data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    set by the test's subprocess)."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
