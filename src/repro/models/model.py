"""Uniform model API over all 10 architectures + input_specs for the
dry-run (ShapeDtypeStruct stand-ins, weak-type-correct, no allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import rwkv6, transformer, whisper, zamba2
from repro.models.sharding import MeshRules, NO_MESH


def family_module(cfg: ArchConfig):
    if cfg.is_encoder_decoder:
        return whisper
    if cfg.ssm_kind == "rwkv6":
        return rwkv6
    if cfg.shared_attn_every:
        return zamba2
    return transformer


def init_params(key, cfg: ArchConfig):
    return family_module(cfg).init_params(key, cfg)


def logical_params(cfg: ArchConfig, rules: MeshRules, *, decode: bool = False):
    mod = family_module(cfg)
    if mod is transformer:
        return mod.logical_tree(cfg, rules, decode=decode)
    return mod.logical_tree(cfg, rules)


# ------------------------------------------------------------------- losses
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_loss(params, cfg: ArchConfig, batch: dict, *, rules: MeshRules = NO_MESH,
               chunk: int = 1024, remat: bool = True) -> jax.Array:
    """Token-level LM loss (teacher-forced for enc-dec). MoE aux included."""
    mod = family_module(cfg)
    if cfg.is_encoder_decoder:
        logits, aux = mod.forward(
            params, cfg, batch["frames"], batch["tokens"], rules=rules,
            chunk=chunk, remat=remat)
    elif cfg.ssm_kind == "rwkv6":
        logits, aux = mod.forward(params, cfg, batch["tokens"], rules=rules,
                                  remat=remat)
    elif cfg.shared_attn_every:
        logits, aux = mod.forward(params, cfg, batch["tokens"], rules=rules,
                                  attn_chunk=chunk, remat=remat)
    else:
        logits, aux = mod.forward(
            params, cfg, batch["tokens"], rules=rules, chunk=chunk,
            remat=remat, pos3=batch.get("pos3"),
            vision_embeds=batch.get("vision_embeds"))
    loss = cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux


# ---------------------------------------------------------------- serve API
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               rules: MeshRules = NO_MESH, kv_dtype: str = "bf16"):
    mod = family_module(cfg)
    if cfg.is_encoder_decoder:
        raise ValueError("whisper serve state is built by serve.prefill")
    if cfg.ssm_kind == "rwkv6":
        return mod.init_state(cfg, batch, rules)
    if mod is transformer:
        return mod.init_cache(cfg, batch, max_len, rules, kv_dtype=kv_dtype)
    return mod.init_cache(cfg, batch, max_len, rules)


def cache_logical(cfg: ArchConfig, rules: MeshRules = NO_MESH,
                  kv_dtype: str = "bf16"):
    mod = family_module(cfg)
    if cfg.ssm_kind == "rwkv6":
        return mod.state_logical(cfg)
    if mod is transformer:
        return mod.cache_logical(cfg, rules, kv_dtype=kv_dtype)
    return mod.cache_logical(cfg, rules)


def decode_step(params, cfg: ArchConfig, token, cache, *, rules=NO_MESH,
                chunk: int = 4096, pos3=None):
    mod = family_module(cfg)
    if cfg.ssm_kind == "rwkv6":
        return mod.decode_step(params, cfg, token, cache, rules=rules)
    if cfg.shared_attn_every:
        return mod.decode_step(params, cfg, token, cache, rules=rules,
                               attn_chunk=chunk)
    return mod.decode_step(params, cfg, token, cache, rules=rules,
                           chunk=chunk, pos3=pos3)


# -------------------------------------------------------------- input specs
def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, include_labels=True):
    """ShapeDtypeStruct stand-ins for every model input of the given shape
    cell (dry-run pattern: shardable, no device allocation). Frontends are
    stubs: whisper gets frame embeddings, qwen2-vl patch embeddings."""
    b, t = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            td = min(cfg.max_decoder_len, t)
            specs = {
                "frames": _sd((b, t, cfg.d_model), jnp.bfloat16),
                "tokens": _sd((b, td), jnp.int32),
            }
            if include_labels and shape.kind == "train":
                specs["labels"] = _sd((b, td), jnp.int32)
            return specs
        specs = {"tokens": _sd((b, t), jnp.int32)}
        if cfg.mrope:
            specs["pos3"] = _sd((3, b, t), jnp.int32)
            specs["vision_embeds"] = _sd((b, min(256, t), cfg.d_model), jnp.bfloat16)
        if include_labels and shape.kind == "train":
            specs["labels"] = _sd((b, t), jnp.int32)
        return specs
    # decode: one new token against a seq_len cache
    specs = {"token": _sd((b,), jnp.int32)}
    if cfg.mrope:
        specs["pos3"] = _sd((3, b, 1), jnp.int32)
    return specs


def batch_logical(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Logical sharding of the input batch."""
    if shape.kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            out = {"frames": ("batch", None, None), "tokens": ("batch", None)}
            if shape.kind == "train":
                out["labels"] = ("batch", None)
            return out
        out = {"tokens": ("batch", None)}
        if cfg.mrope:
            out["pos3"] = (None, "batch", None)
            out["vision_embeds"] = ("batch", None, None)
        if shape.kind == "train":
            out["labels"] = ("batch", None)
        return out
    out = {"token": ("batch",)}
    if cfg.mrope:
        out["pos3"] = (None, "batch", None)
    return out
