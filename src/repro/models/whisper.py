"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Encoder consumes precomputed frame embeddings (B, T_enc, d) — the conv
frontend is a stub per the assignment; `input_specs()` supplies the
embeddings. Sinusoidal positions, bidirectional self-attention, plain GELU
MLP. Decoder: causal self-attention (cached for decode) + cross-attention
to the encoder memory (K/V precomputed once at prefill).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import MeshRules, NO_MESH


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def sinusoid(t: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def init_plain_mlp(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "wi": L._dense_init(ks[0], (cfg.d_model, cfg.d_ff), cfg.d_model, dtype),
        "wo": L._dense_init(ks[1], (cfg.d_ff, cfg.d_model), cfg.d_ff, dtype),
    }


def logical_plain_mlp():
    return {"wi": ("d", "tp"), "wo": ("tp", "d")}


def plain_mlp(p, x):
    return jnp.einsum("btf,fd->btd", jax.nn.gelu(
        jnp.einsum("btd,df->btf", x, p["wi"])), p["wo"])


def init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_plain_mlp(ks[1], cfg, dtype),
    }


def init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self_attn": L.init_attention(ks[0], cfg, dtype),
        "ln_x": jnp.zeros((cfg.d_model,), dtype),
        "cross_attn": L.init_attention(ks[1], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": init_plain_mlp(ks[2], cfg, dtype),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(
        jax.random.split(k_enc, cfg.encoder_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(
        jax.random.split(k_dec, cfg.num_layers))
    return {
        "embed": L.init_embed(k_embed, cfg, dtype),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def logical_tree(cfg: ArchConfig, rules: MeshRules) -> dict:
    stack = lambda tree: jax.tree.map(
        lambda lg: (None, *lg), tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    mode = L.attn_shard_mode(cfg, rules)
    enc = {"ln1": (None,), "attn": L.logical_attention(cfg, mode),
           "ln2": (None,), "mlp": logical_plain_mlp()}
    dec = {"ln1": (None,), "self_attn": L.logical_attention(cfg, mode),
           "ln_x": (None,), "cross_attn": L.logical_attention(cfg, mode),
           "ln2": (None,), "mlp": logical_plain_mlp()}
    return {
        "embed": L.logical_embed(cfg),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": (None,), "dec_norm": (None,),
    }


# ------------------------------------------------------------------ encoder
def encode(params, cfg, frames, *, rules=NO_MESH, chunk=1024, remat=True):
    """frames: (B, T_enc, d) stub embeddings -> (B, T_enc, d) memory."""
    b, t, d = frames.shape
    x = frames.astype(_dtype(cfg)) + sinusoid(t, d).astype(_dtype(cfg))
    x = rules.constrain(x, ("batch", None, None))
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, cfg)
        o = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=False,
                                chunk=chunk, rules=rules)
        x = x + L.attention_out(lp["attn"], o)
        x = x + plain_mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps))
        return rules.constrain(x, ("batch", None, None)), None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ------------------------------------------------------------------ decoder
def cross_kv(params, cfg, memory, rules=NO_MESH):
    """Precompute cross-attention K/V for all decoder layers:
    (L, B, T_enc, kv, hd) each, kv heads sharded on the tensor axis."""
    def per_layer(lp):
        k = jnp.einsum("btd,dhk->bthk", memory, lp["cross_attn"]["wk"])
        v = jnp.einsum("btd,dhk->bthk", memory, lp["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + lp["cross_attn"]["bk"]
            v = v + lp["cross_attn"]["bv"]
        return k, v
    xk, xv = jax.vmap(per_layer)(params["dec_layers"])
    xk = rules.constrain(xk, (None, "batch", None, "tp", None))
    xv = rules.constrain(xv, (None, "batch", None, "tp", None))
    return xk, xv


def decode(params, cfg, tokens, memory=None, *, xk=None, xv=None,
           self_cache=None, rules=NO_MESH, chunk=1024, remat=True,
           start_pos=0):
    """Decoder forward. Either `memory` (computes cross K/V) or
    precomputed (xk, xv). self_cache: {"k","v","pos","idx"} stacked (L,...)
    for incremental decoding; None for teacher-forced training."""
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    d = x.shape[-1]
    if xk is None:
        xk, xv = cross_kv(params, cfg, memory)
    enc_t = xk.shape[2]
    mem_pos = jnp.broadcast_to(jnp.arange(enc_t, dtype=jnp.int32)[None],
                               (b, enc_t))
    idx = self_cache["idx"] if self_cache is not None else jnp.array(0, jnp.int32)
    q_pos = idx[None, None] + jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[None], (b, t)) if self_cache is not None \
        else jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    x = x + jnp.take(sinusoid(cfg.max_decoder_len, d).astype(x.dtype),
                     jnp.clip(q_pos[0], 0, cfg.max_decoder_len - 1), axis=0)
    x = rules.constrain(x, ("batch", None, None))

    use_cache = self_cache is not None
    if use_cache:
        kv_pos = jax.lax.dynamic_update_slice(self_cache["pos"], q_pos, (0, idx))

    def body(x, xs):
        if use_cache:
            lp, xk_l, xv_l, kc, vc = xs
        else:
            lp, xk_l, xv_l = xs
            kc = vc = None
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["self_attn"], h, cfg)
        if use_cache:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                              (0, idx, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                              (0, idx, 0, 0))
            o = L.chunked_attention(q, kc, vc, q_pos=q_pos, kv_pos=kv_pos,
                                    causal=True, chunk=chunk, rules=rules)
        else:
            o = L.chunked_attention(q, k, v, q_pos=q_pos, kv_pos=q_pos,
                                    causal=True, chunk=chunk, rules=rules)
        x = x + L.attention_out(lp["self_attn"], o)
        hx = L.rms_norm(x, lp["ln_x"], cfg.norm_eps)
        qx = jnp.einsum("btd,dhk->bthk", hx, lp["cross_attn"]["wq"])
        if cfg.qkv_bias:
            qx = qx + lp["cross_attn"]["bq"]
        ox = L.chunked_attention(qx, xk_l, xv_l, q_pos=q_pos, kv_pos=mem_pos,
                                 causal=False, chunk=chunk, rules=rules)
        x = x + L.attention_out(lp["cross_attn"], ox)
        x = x + plain_mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps))
        x = rules.constrain(x, ("batch", None, None))
        ys = (kc, vc) if use_cache else None
        return x, ys

    fn = jax.checkpoint(body) if (remat and not use_cache) else body
    if use_cache:
        x, (k_new, v_new) = jax.lax.scan(
            fn, x, (params["dec_layers"], xk, xv,
                    self_cache["k"], self_cache["v"]))
    else:
        x, _ = jax.lax.scan(fn, x, (params["dec_layers"], xk, xv))
    x = L.rms_norm(x, params["dec_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    if use_cache:
        new_cache = {
            "k": k_new, "v": v_new,
            "pos": kv_pos, "idx": idx + t,
        }
        return logits, new_cache
    return logits, jnp.zeros((), jnp.float32)


def init_self_cache(cfg, batch, max_len, rules=NO_MESH):
    kv, hd = cfg.num_kv_heads, cfg.hd
    dtype = _dtype(cfg)
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def forward(params, cfg, frames, tokens, *, rules=NO_MESH, chunk=1024,
            remat=True):
    """Teacher-forced train forward: (enc frames, dec tokens) -> logits."""
    memory = encode(params, cfg, frames, rules=rules, chunk=chunk, remat=remat)
    return decode(params, cfg, tokens, memory, rules=rules, chunk=chunk,
                  remat=remat)
