"""Shared layer library: RMSNorm, RoPE/M-RoPE, chunked GQA attention,
SwiGLU/GeGLU MLP, GShard-style MoE, embeddings.

All functions are pure; params are nested dicts of jnp arrays, and every
init_* has a matching logical_* tree (see models/sharding.py) used to build
PartitionSpecs. Math runs in f32 where numerics demand (softmax, norms,
router), bf16 elsewhere.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.models.sharding import MeshRules, NO_MESH

# --------------------------------------------------------------------- utils
def _dense_init(key, shape, in_dim, dtype):
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def act_fn(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


# ---------------------------------------------------------------------- RoPE
def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, hd); pos: (B, T) absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, pos3: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. pos3: (3, B, T) = (temporal, h, w) ids;
    frequency dims split into `sections` (sums to hd/2), each section
    rotated by its own position stream."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # per-frequency position stream
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=hd // 2
    )                                                    # (hd/2,) in {0,1,2}
    pos_sel = jnp.take(pos3, sec_ids, axis=0)            # (hd/2, B, T)
    angles = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32) * freqs  # (B,T,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def _mask_chunk(p_i, q_pos, causal: bool, window):
    """(B,1,1,Tq?,chunk) validity mask pieces; p_i: (B,chunk); q_pos: (B,Tq)."""
    valid = p_i[:, None, None, None, :] >= 0
    if causal:
        valid &= p_i[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    apply_window = not (isinstance(window, int) and window == 0)
    if apply_window:
        w = jnp.asarray(window, jnp.int32)
        in_window = p_i[:, None, None, None, :] > (
            q_pos[:, None, None, :, None] - w
        )
        valid &= in_window | (w <= 0)   # w==0 -> global layer (gemma3)
    return valid


def _flash_fwd_scan(qg, kc, vc, pc, q_pos, causal, window, scale):
    """Online-softmax forward. qg: (B,Kv,G,Tq,hd); kc/vc: (n,B,chunk,Kv,hd);
    pc: (n,B,chunk). Returns (out f32, lse f32) with lse = m + log l."""
    b, kv_heads, g, tq, hd = qg.shape

    def step(carry, xs):
        acc, m, l = carry
        k_i, v_i, p_i = xs
        sc = jnp.einsum(
            "bkgth,bckh->bkgtc", qg, k_i.astype(qg.dtype),
            preferred_element_type=jnp.float32,
        ) * scale                                   # (B,Kv,G,Tq,chunk)
        valid = _mask_chunk(p_i, q_pos, causal, window)
        sc = jnp.where(valid, sc, -jnp.inf)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(valid, jnp.exp(sc - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtc,bckh->bkgth", p.astype(qg.dtype), v_i.astype(qg.dtype),
            preferred_element_type=jnp.float32,
        )
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, kv_heads, g, tq, hd), dtype=jnp.float32)
    m0 = jnp.full((b, kv_heads, g, tq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, kv_heads, g, tq), dtype=jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    lse = jnp.where(jnp.isfinite(m), m, 0.0) + jnp.log(
        jnp.maximum(l, 1e-30))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 7))
def _flash(qg, kc, vc, pc, q_pos, causal, window, scale):
    out, _ = _flash_fwd_scan(qg, kc, vc, pc, q_pos, causal, window, scale)
    return out


def _flash_fwd(qg, kc, vc, pc, q_pos, causal, window, scale):
    out, lse = _flash_fwd_scan(qg, kc, vc, pc, q_pos, causal, window, scale)
    return out, (qg, kc, vc, pc, q_pos, window, out, lse)


def _flash_bwd(causal, scale, res, do):
    """Flash backward: re-stream KV chunks, recompute p from lse — O(Tq *
    chunk) live memory instead of O(Tq * S) saved residuals."""
    qg, kc, vc, pc, q_pos, window, out, lse = res
    do = do.astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1)                    # (B,Kv,G,Tq)

    def step(dq, xs):
        k_i, v_i, p_i = xs
        sc = jnp.einsum(
            "bkgth,bckh->bkgtc", qg, k_i.astype(qg.dtype),
            preferred_element_type=jnp.float32,
        ) * scale
        valid = _mask_chunk(p_i, q_pos, causal, window)
        p = jnp.where(valid, jnp.exp(sc - lse[..., None]), 0.0)
        dv_i = jnp.einsum("bkgtc,bkgth->bckh", p.astype(do.dtype), do)
        dp = jnp.einsum("bkgth,bckh->bkgtc", do, v_i.astype(do.dtype))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgtc,bckh->bkgth", ds.astype(qg.dtype),
                             k_i.astype(qg.dtype),
                             preferred_element_type=jnp.float32)
        dk_i = jnp.einsum("bkgtc,bkgth->bckh", ds.astype(qg.dtype), qg,
                          preferred_element_type=jnp.float32)
        return dq, (dk_i, dv_i)

    dq0 = jnp.zeros(qg.shape, jnp.float32)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kc, vc, pc))
    # cotangents for (qg, kc, vc, pc, q_pos, window) — ints get None
    return (dq.astype(qg.dtype), dk.astype(kc.dtype), dv.astype(vc.dtype),
            None, None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(
    q: jax.Array,                  # (B, Tq, H, hd)
    k: jax.Array,                  # (B, S, Kv, hd)
    v: jax.Array,                  # (B, S, Kv, hd)
    *,
    q_pos: jax.Array,              # (B, Tq) absolute positions
    kv_pos: jax.Array,             # (B, S) absolute positions; -1 = invalid
    causal: bool = True,
    window: int | jax.Array = 0,   # 0 = full; >0 = sliding window size;
                                   # may be a traced scalar (per-layer scan)
    chunk: int = 1024,
    rules: MeshRules = NO_MESH,
    k_scale: jax.Array | None = None,   # (B, S, Kv): int8-KV dequant scales
    v_scale: jax.Array | None = None,   # (decode fast path only)
) -> jax.Array:
    """Flash attention (online softmax over KV chunks) with a custom VJP.

    Pure jnp + lax.scan: O(Tq * chunk) live memory in BOTH directions; the
    backward pass re-streams the KV chunks and recomputes probabilities
    from the saved logsumexp instead of keeping O(Tq * S) scan residuals.
    Lowers on any backend (DESIGN.md section 7).
    """
    b, tq, h, hd = q.shape
    s, kv_heads = k.shape[1], k.shape[2]
    g = h // kv_heads
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    n_chunks = k.shape[1] // chunk
    quantized = k_scale is not None
    assert not (quantized and tq != 1), "int8 KV is a decode-path feature"

    if tq == 1:
        # decode fast path: stream KV chunks with dynamic slices on the
        # native (B, S, Kv, hd) cache layout — the scan path's reshape/
        # moveaxis would materialize a transposed copy of the whole cache
        # every layer, every step (EXPERIMENTS.md Perf, decode iteration)
        qg1 = jnp.moveaxis(q.reshape(b, 1, kv_heads, g, hd), 1, 3
                           ).astype(jnp.bfloat16)
        scale1 = 1.0 / math.sqrt(hd)

        def dstep(c, carry):
            acc, m, l = carry
            k_i = jax.lax.dynamic_slice_in_dim(k, c * chunk, chunk, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, c * chunk, chunk, axis=1)
            p_i = jax.lax.dynamic_slice_in_dim(kv_pos, c * chunk, chunk,
                                               axis=1)
            if quantized:
                # per-chunk dequant keeps the bf16 copy chunk-sized — the
                # whole-cache dequant would forfeit the int8 memory win
                ks_i = jax.lax.dynamic_slice_in_dim(k_scale, c * chunk,
                                                    chunk, axis=1)
                vs_i = jax.lax.dynamic_slice_in_dim(v_scale, c * chunk,
                                                    chunk, axis=1)
                k_i = (k_i.astype(jnp.bfloat16)
                       * ks_i[..., None].astype(jnp.bfloat16))
                v_i = (v_i.astype(jnp.bfloat16)
                       * vs_i[..., None].astype(jnp.bfloat16))
            sc = jnp.einsum(
                "bkgth,bckh->bkgtc", qg1, k_i.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32) * scale1
            valid = _mask_chunk(p_i, q_pos, causal, window)
            sc = jnp.where(valid, sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(valid, jnp.exp(sc - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgtc,bckh->bkgth", p.astype(jnp.bfloat16),
                v_i.astype(jnp.bfloat16),
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new)

        init = (jnp.zeros((b, kv_heads, g, 1, hd), jnp.float32),
                jnp.full((b, kv_heads, g, 1), -jnp.inf, jnp.float32),
                jnp.zeros((b, kv_heads, g, 1), jnp.float32))
        acc, m, l = jax.lax.fori_loop(0, n_chunks, dstep, init)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1).reshape(b, 1, h, hd)
        return out.astype(q.dtype)

    qg = jnp.moveaxis(
        q.reshape(b, tq, kv_heads, g, hd), 1, 3
    ).astype(jnp.bfloat16)                                # (B,Kv,G,Tq,hd)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, kv_heads, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, kv_heads, hd), 1, 0)
    pc = jnp.moveaxis(kv_pos.reshape(b, n_chunks, chunk), 1, 0)
    scale = 1.0 / math.sqrt(hd)

    window_arr = (jnp.asarray(window, jnp.int32) if not isinstance(window, int)
                  else jnp.asarray(window, jnp.int32))
    out = _flash(qg, kc, vc, pc, q_pos, causal, window_arr, scale)
    out = jnp.moveaxis(out, 3, 1).reshape(b, tq, h, hd)   # (B,Tq,H,hd)
    return out.astype(q.dtype)


# --------------------------------------------------------------- GQA module
def attn_shard_mode(cfg, rules: MeshRules, *, decode: bool = False) -> str:
    """Tensor-shard layout when heads don't divide the tensor axis
    (smollm 15H, gemma 8H, qwen2 12H on a 16-way axis):

    * full-sequence steps (train/prefill) -> "seq": whole-layer sequence
      parallelism (activations T-sharded, layer weights fsdp-only).
      head_dim sharding was tried first and refuted: the QK contraction
      over the sharded hd all-reduces score-sized tensors every chunk
      (EXPERIMENTS.md Perf/smollm iteration 1).
    * decode (Tq=1) -> "hd" when head_dim divides: scores are tiny, and
      hd-sharding splits the KV cache + weight reads 16 ways.
    """
    if rules.mesh is None:
        return "none"
    ts = rules.mesh.shape[rules.tensor]
    if cfg.num_heads % ts == 0 and cfg.num_kv_heads % ts == 0:
        return "heads"
    if cfg.num_heads % ts == 0 and not decode:
        # grok-1: 48 Q-heads shard 16 ways; its 8 KV heads replicate and
        # expand to MHA per shard (KV weights/activations are small)
        return "heads_repkv"
    if decode:
        return "hd" if cfg.hd % ts == 0 else "none"
    return "seq"


def init_attention(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, kv, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, kv, hd), d, dtype),
        "wo": _dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def logical_attention(cfg, mode: str = "heads") -> dict:
    if mode == "heads_repkv":
        t = {
            "wq": ("d", "tp", None),
            "wk": ("d", None, None),
            "wv": ("d", None, None),
            "wo": ("tp", None, "d"),
        }
        if cfg.qkv_bias:
            t |= {"bq": ("tp", None), "bk": (None, None), "bv": (None, None)}
        return t
    if mode == "hd":
        t = {
            "wq": ("d", None, "tp"),
            "wk": ("d", None, "tp"),
            "wv": ("d", None, "tp"),
            "wo": (None, "tp", "d"),
        }
        bias = {"bq": (None, "tp"), "bk": (None, "tp"), "bv": (None, "tp")}
    else:
        t = {
            "wq": ("d", "tp", None),
            "wk": ("d", "tp", None),
            "wv": ("d", "tp", None),
            "wo": ("tp", None, "d"),
        }
        bias = {"bq": ("tp", None), "bk": ("tp", None), "bv": ("tp", None)}
    if cfg.qkv_bias:
        t |= bias
    return t


def attention_qkv(params, x, cfg):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def attention_out(params, o):
    return jnp.einsum("bthk,hkd->btd", o, params["wo"])


# ----------------------------------------------------------------------- MLP
def init_mlp(key, cfg, dtype, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": _dense_init(ks[0], (d, f), d, dtype),
        "wi_up": _dense_init(ks[1], (d, f), d, dtype),
        "wo": _dense_init(ks[2], (f, d), f, dtype),
    }


def logical_mlp(cfg) -> dict:
    return {"wi_gate": ("d", "tp"), "wi_up": ("d", "tp"), "wo": ("tp", "d")}


def mlp(params, x, cfg):
    gate = jnp.einsum("btd,df->btf", x, params["wi_gate"])
    up = jnp.einsum("btd,df->btf", x, params["wi_up"])
    return jnp.einsum("btf,fd->btd", act_fn(cfg.act)(gate) * up, params["wo"])


# ----------------------------------------------------------------------- MoE
def init_moe(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), d, jnp.float32),
        "wi_gate": _dense_init(ks[1], (e, d, f), d, dtype),
        "wi_up": _dense_init(ks[2], (e, d, f), d, dtype),
        "wo": _dense_init(ks[3], (e, f, d), f, dtype),
    }


def logical_moe(cfg, ep: bool) -> dict:
    """ep=True: experts sharded over tensor axis (expert parallelism);
    else tensor-parallel inside each expert (grok-1: 8 experts < 16-way)."""
    if ep:
        return {
            "router": ("d", None),
            "wi_gate": ("tp", "d", None),
            "wi_up": ("tp", "d", None),
            "wo": ("tp", None, "d"),
        }
    return {
        "router": ("d", None),
        "wi_gate": (None, "d", "tp"),
        "wi_up": (None, "d", "tp"),
        "wo": (None, "tp", "d"),
    }


@dataclasses.dataclass
class MoEAux:
    load_balance_loss: jax.Array


def moe(params, x, cfg, rules: MeshRules = NO_MESH,
        group_size: int = 2048) -> tuple[jax.Array, MoEAux]:
    """GShard-style dense-dispatch MoE (einsum formulation, shardable
    without ragged ops).

    Tokens are split into groups of `group_size` with per-group capacity —
    the dispatch/combine tensors are (groups, G, E, C) with C = G*k*cf/E,
    i.e. total size b*t*G*k*cf: linear in G, so small groups keep the
    dispatch footprint bounded at long sequence lengths (32k prefill would
    otherwise materialize multi-GiB one-hots per layer).
    """
    mcfg = cfg.moe
    b_in, t_in, d = x.shape
    g_sz = min(group_size, t_in)
    if t_in % g_sz:
        g_sz = t_in                      # fallback: one group per sequence
    x = x.reshape(b_in * (t_in // g_sz), g_sz, d)
    b, t, _ = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    cap = int(math.ceil(t * k * mcfg.capacity_factor / e))
    cap = min(cap, t)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (b,t,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # position of each (token, slot) in its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)        # (b,t,k,e)
    flat = onehot.reshape(b, t * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                # (b,t*k,e)
    pos = (pos_in_expert * flat).sum(-1).reshape(b, t, k)          # (b,t,k)
    expert_sel = onehot                                            # (b,t,k,e)
    keep = (pos < cap).astype(jnp.float32)
    cap_onehot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    # dispatch/combine tensors (b, t, e, cap)
    dispatch = jnp.einsum("btke,btkc,btk->btec", expert_sel, cap_onehot, keep)
    combine = jnp.einsum(
        "btke,btkc,btk,btk->btec", expert_sel, cap_onehot, keep, gate_vals
    )

    xb = x.astype(jnp.bfloat16)
    expert_in = jnp.einsum(
        "btec,btd->becd", dispatch.astype(jnp.bfloat16), xb
    )                                                              # (b,e,cap,d)
    expert_in = rules.constrain(expert_in, ("batch", "tp", None, None))
    gate_h = jnp.einsum("becd,edf->becf", expert_in, params["wi_gate"])
    up_h = jnp.einsum("becd,edf->becf", expert_in, params["wi_up"])
    h = act_fn(cfg.act)(gate_h) * up_h
    expert_out = jnp.einsum("becf,efd->becd", h, params["wo"])
    expert_out = rules.constrain(expert_out, ("batch", "tp", None, None))
    out = jnp.einsum(
        "btec,becd->btd", combine.astype(jnp.bfloat16), expert_out
    ).astype(x.dtype)
    out = out.reshape(b_in, t_in, d)

    # switch-style load balance aux: E * sum(frac_tokens_e * frac_prob_e)
    frac_tokens = onehot[:, :, 0, :].mean(axis=(0, 1))             # top-1 share
    frac_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, MoEAux(load_balance_loss=aux)


# ----------------------------------------------------------------- embedding
def init_embed(key, cfg, dtype) -> dict:
    return {
        "table": _dense_init(key, (cfg.vocab_size, cfg.d_model), cfg.d_model, dtype)
    }


def logical_embed(cfg) -> dict:
    return {"table": ("tp", "d")}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    return jnp.einsum(
        "btd,vd->btv", x.astype(jnp.bfloat16), params["table"].astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )


# ------------------------------------------------------------ int8 KV cache
def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, T, Kv, hd) bf16 -> (int8 values, (B, T, Kv) f16 scales).

    Per-(token, head) absmax scaling — halves KV-cache HBM (the
    moonlight/grok decode_32k single-pod fit, EXPERIMENTS.md section 6)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)
