"""Mamba2 (SSD) block — scalar-per-head decay state-space model.

Per head (P = head dim, N = ssm state):
  h_t = a_t h_{t-1} + (dt_t x_t) (x) B_t          h: (P, N)
  y_t = h_t C_t + D x_t
  a_t = exp(-softplus(dt_raw_t + dt_bias) * exp(A_log))   (scalar/head)
Chunked-parallel prefill (the SSD algorithm): with scalar decays the
intra-chunk pair matrix exp(cs_i - cs_j) (i >= j) is computed directly —
exponents are <= 0, no clamping needed. Short causal conv (kernel 4) over
the x/B/C channels; decode keeps a rolling conv buffer + the SSM state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import MeshRules, NO_MESH

CONV_K = 4
MAMBA_HEAD_DIM = 64


def dims(cfg: ArchConfig):
    d_in = 2 * cfg.d_model
    nheads = d_in // MAMBA_HEAD_DIM
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n
    return d_in, nheads, n, conv_dim


def init_layer(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nheads, n, conv_dim = dims(cfg)
    ks = iter(jax.random.split(key, 8))
    return {
        "ln": jnp.zeros((d,), dtype),
        # in_proj -> [z (d_in), x (d_in), B (n), C (n), dt (nheads)]
        "w_in": L._dense_init(next(ks), (d, 2 * d_in + 2 * n + nheads), d, dtype),
        "conv_w": L._dense_init(next(ks), (CONV_K, conv_dim), CONV_K, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "out_ln": jnp.zeros((d_in,), dtype),
        "w_out": L._dense_init(next(ks), (d_in, d), d_in, dtype),
    }


def logical_layer(cfg: ArchConfig) -> dict:
    return {
        "ln": (None,),
        "w_in": ("d", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "A_log": (None,), "dt_bias": (None,), "D": (None,),
        "out_ln": ("tp",),
        "w_out": ("tp", "d"),
    }


def _split(zxbcdt, cfg):
    d_in, nheads, n, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + d_in + 2 * n]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _conv(xbc, conv_w, conv_b, conv_state):
    """Causal depthwise conv, kernel CONV_K. conv_state: (B, CONV_K-1, C)
    carries the last inputs from the previous segment."""
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    t = xbc.shape[1]
    for i in range(CONV_K):
        out = out + full[:, i: i + t] * conv_w[i]
    new_state = full[:, -(CONV_K - 1):]
    return jax.nn.silu(out + conv_b), new_state


def ssd_chunked(x, b_t, c_t, dt, lp, state, chunk: int):
    """x: (B,T,H,P) f32; b_t,c_t: (B,T,N); dt: (B,T,H); state: (B,H,P,N)."""
    bsz, t, h, p = x.shape
    n = b_t.shape[-1]
    dt_s = jax.nn.softplus(dt + lp["dt_bias"])                # (B,T,H)
    loga = -dt_s * jnp.exp(lp["A_log"])                       # <= 0
    dtx = x * dt_s[..., None]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    nchunks = dtx.shape[1] // chunk
    r4 = lambda z: jnp.moveaxis(z.reshape(bsz, nchunks, chunk, *z.shape[2:]), 1, 0)
    xs_all = (r4(dtx), r4(b_t), r4(c_t), r4(loga))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))          # j <= i

    def step(S, xs):
        dx, bb, cc, la = xs                   # (B,C,H,P) (B,C,N) (B,C,H)
        cs = jnp.cumsum(la, axis=1)           # (B,C,H) decreasing
        # inter: y_i += C_i . (exp(cs_i) h0)
        y_inter = jnp.einsum("bcn,bhpn,bch->bchp", cc, S, jnp.exp(cs))
        # intra: pair (B,H,C,C): exp(cs_i - cs_j) * (C_i . B_j), j <= i
        pair = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B,i,j,H)
        pair = jnp.where(causal[None, :, :, None], pair, 0.0)
        cb = jnp.einsum("bin,bjn->bij", cc, bb)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, pair, dx)
        # state update: h_L = exp(cs_L) h0 + sum_j exp(cs_L - cs_j) dx_j (x) B_j
        decay_end = jnp.exp(cs[:, -1:, :] - cs)               # (B,C,H)
        S_new = S * jnp.exp(cs[:, -1])[..., None, None] + jnp.einsum(
            "bchp,bcn,bch->bhpn", dx, bb, decay_end
        )
        return S_new, y_inter + y_intra

    state, ys = jax.lax.scan(step, state, xs_all)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nchunks * chunk, h, p)[:, :t]
    return y, state


def block(lp, x, cfg, state, *, chunk: int, rules: MeshRules = NO_MESH):
    """One Mamba2 block. state: {"ssm": (B,H,P,N), "conv": (B,K-1,conv_dim)}.
    Returns (out, new_state)."""
    bsz, t, d = x.shape
    d_in, nheads, n, conv_dim = dims(cfg)
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", h, lp["w_in"])
    z, xbc, dt = _split(zxbcdt, cfg)
    xbc, conv_new = _conv(xbc, lp["conv_w"], lp["conv_b"], state["conv"])
    xin = xbc[..., :d_in].astype(jnp.float32).reshape(bsz, t, nheads, MAMBA_HEAD_DIM)
    b_t = xbc[..., d_in: d_in + n].astype(jnp.float32)
    c_t = xbc[..., d_in + n:].astype(jnp.float32)
    y, ssm_new = ssd_chunked(
        xin, b_t, c_t, dt.astype(jnp.float32), lp, state["ssm"], chunk
    )
    y = y + lp["D"][None, None, :, None] * xin
    y = y.reshape(bsz, t, d_in).astype(x.dtype) * jax.nn.silu(z)
    y = L.rms_norm(y, lp["out_ln"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, lp["w_out"])
    new_state = {"ssm": ssm_new, "conv": conv_new.astype(state["conv"].dtype)}
    return out, new_state


def init_state(cfg: ArchConfig, batch: int, num_layers: int,
               rules: MeshRules = NO_MESH, dtype=jnp.bfloat16):
    d_in, nheads, n, conv_dim = dims(cfg)
    s = {
        "ssm": jnp.zeros((num_layers, batch, nheads, MAMBA_HEAD_DIM, n),
                         jnp.float32),
        "conv": jnp.zeros((num_layers, batch, CONV_K - 1, conv_dim), dtype),
    }
    s["ssm"] = rules.constrain(s["ssm"], (None, "batch", "tp", None, None))
    s["conv"] = rules.constrain(s["conv"], (None, "batch", None, "tp"))
    return s


def state_logical(cfg: ArchConfig) -> dict:
    return {
        "ssm": (None, "batch", "tp", None, None),
        "conv": (None, "batch", None, "tp"),
    }
