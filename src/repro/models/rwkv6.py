"""RWKV6 "Finch" — attention-free RNN with data-dependent per-channel decay.

Recurrence per head (K = V = head_dim):
  S_t = diag(w_t) S_{t-1} + k_t^T v_t
  o_t = r_t (S_{t-1} + diag(u (.) k_t)^T v_t)        (u = bonus)
with w_t in (0,1)^K produced data-dependently (LoRA on the shifted input).

Prefill uses the chunked-parallel form (chunk C): within a chunk, with
cs = cumsum(log w) (negative, decreasing), decayed queries r~_i = r_i *
exp(cs_{i-1} - cs_ref) and inflated keys k~_j = k_j * exp(cs_ref - cs_j)
make the intra-chunk term a masked (r~ k~^T) v matmul whose exponents are
bounded by the per-chunk total decay; we clamp log w at -LOG_CLAMP/C per
step so exp stays in f32 range (decays stronger than that are numerically
zero after a couple of steps anyway). Decode is the plain O(1) recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import MeshRules, NO_MESH

LOG_CLAMP = 40.0  # max total |log-decay| per chunk (exp(40) ~ 2e17, f32-safe)


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _mix_names():
    return ("r", "k", "v", "g", "w")


def init_layer(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    h, hd = cfg.num_heads, cfg.hd
    assert h * hd == d, "rwkv6 requires num_heads*head_dim == d_model"
    lora = max(32, d // 32)
    ks = iter(jax.random.split(key, 16))
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "ln2": jnp.zeros((d,), dtype),
        "mix": {f"mu_{n}": jnp.full((d,), 0.5, dtype) for n in _mix_names()},
        "wr": L._dense_init(next(ks), (d, d), d, dtype),
        "wk": L._dense_init(next(ks), (d, d), d, dtype),
        "wv": L._dense_init(next(ks), (d, d), d, dtype),
        "wg": L._dense_init(next(ks), (d, d), d, dtype),
        "wo": L._dense_init(next(ks), (d, d), d, dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "wA": L._dense_init(next(ks), (d, lora), d, dtype),
        "wB": L._dense_init(next(ks), (lora, d), lora, dtype),
        "u": jnp.zeros((d,), jnp.float32),
        "head_ln": jnp.zeros((h, hd), dtype),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": L._dense_init(next(ks), (d, cfg.d_ff), d, dtype),
        "cm_wv": L._dense_init(next(ks), (cfg.d_ff, d), cfg.d_ff, dtype),
        "cm_wr": L._dense_init(next(ks), (d, d), d, dtype),
    }
    return p


def logical_layer(cfg: ArchConfig) -> dict:
    d2 = ("d", "tp")
    return {
        "ln1": (None,), "ln2": (None,),
        "mix": {f"mu_{n}": (None,) for n in _mix_names()},
        "wr": d2, "wk": d2, "wv": d2, "wg": d2, "wo": ("tp", "d"),
        "w0": (None,), "wA": ("d", None), "wB": (None, "tp"),
        "u": (None,), "head_ln": (None, None),
        "cm_mu_k": (None,), "cm_mu_r": (None,),
        "cm_wk": ("d", "tp"), "cm_wv": ("tp", "d"), "cm_wr": ("d", "tp"),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_layers = jax.random.split(key)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(
        jax.random.split(k_layers, cfg.num_layers)
    )
    return {
        "embed": L.init_embed(k_embed, cfg, dtype),
        "layers": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def logical_tree(cfg: ArchConfig, rules: MeshRules) -> dict:
    per_layer = logical_layer(cfg)
    stacked = jax.tree.map(
        lambda lg: (None, *lg), per_layer,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    return {"embed": L.logical_embed(cfg), "layers": stacked,
            "final_norm": (None,)}


# ------------------------------------------------------------------ wkv core
def _decays(lp, xw, cfg):
    """w in (0,1)^(B,T,d) from the decay LoRA, f32, clamped."""
    lora = jnp.einsum(
        "btd,dl->btl", xw.astype(jnp.float32), lp["wA"].astype(jnp.float32)
    )
    dec = lp["w0"] + jnp.einsum(
        "btl,ld->btd", jnp.tanh(lora), lp["wB"].astype(jnp.float32)
    )
    logw = -jnp.exp(dec)                       # < 0
    return jnp.clip(logw, -LOG_CLAMP / 2, -1e-6)


def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunked-parallel WKV. r,k,v: (B,T,H,K) f32; logw: (B,T,H,K) f32;
    u: (H,K); state: (B,H,K,K). Returns (out (B,T,H,K), new_state)."""
    b, t, h, kk = r.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=-1e-6)
    n = r.shape[1] // chunk
    resh = lambda x: jnp.moveaxis(
        x.reshape(b, n, chunk, h, kk), 1, 0
    )                                           # (n, B, C, H, K)
    rs, ks, vs, lws = resh(r), resh(k), resh(v), resh(logw)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower

    def step(S, xs):
        rc, kc, vc, lw = xs                     # (B,C,H,K)
        cs = jnp.cumsum(lw, axis=1)             # decreasing, <0
        cs_prev = cs - lw                       # cs_{i-1}
        total = cs[:, -1:, :, :]                # (B,1,H,K)
        r_dec = rc * jnp.exp(cs_prev)           # exponent <= 0
        k_inf = kc * jnp.exp(total - cs)        # exponent <= 0
        # inter-chunk: r_i C_{i-1} . S
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_dec, S)
        # intra-chunk: A_ij = (r_i e^{cs_{i-1}}) . (k_j e^{-cs_j}); factor
        # the chunk total into k to keep exponents bounded by |total|<=CLAMP
        a = jnp.einsum("bihk,bjhk->bhij", r_dec, kc * jnp.exp(-cs))
        a = jnp.where(causal[None, None], a, 0.0)
        o_intra = jnp.einsum("bhij,bjhv->bihv", a, vc)
        # diagonal bonus term: (r_i . (u (.) k_i)) v_i
        diag = jnp.einsum("bchk,bchk->bch", rc, kc * u[None, None])
        o_diag = diag[..., None] * vc
        # state to end of chunk
        S_new = S * jnp.exp(total).squeeze(1)[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_inf, vc
        )
        return S_new, o_inter + o_intra + o_diag

    state, outs = jax.lax.scan(step, state, (rs, ks, vs, lws))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n * chunk, h, kk)[:, :t]
    return out, state


# ------------------------------------------------------------------- forward
def _token_shift(x, last):
    """last: (B, d) previous token (zeros at seq start)."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev


def _time_mix(lp, x, cfg, state, last_x, *, chunk, rules):
    b, t, d = x.shape
    h, hd = cfg.num_heads, cfg.hd
    prev = _token_shift(x, last_x)
    mixed = {
        n: x + (prev - x) * lp["mix"][f"mu_{n}"] for n in _mix_names()
    }
    f32 = jnp.float32
    r = jnp.einsum("btd,de->bte", mixed["r"], lp["wr"]).astype(f32)
    k = jnp.einsum("btd,de->bte", mixed["k"], lp["wk"]).astype(f32)
    v = jnp.einsum("btd,de->bte", mixed["v"], lp["wv"]).astype(f32)
    g = jnp.einsum("btd,de->bte", mixed["g"], lp["wg"])
    logw = _decays(lp, mixed["w"], cfg)
    hsplit = lambda z: z.reshape(b, t, h, hd)
    u = lp["u"].reshape(h, hd)
    out, state = wkv_chunked(
        hsplit(r), hsplit(k), hsplit(v), hsplit(logw), u,
        state, chunk=chunk,
    )
    # per-head normalization + gate
    out = L.rms_norm(
        out.astype(_dtype(cfg)), lp["head_ln"][None, None], cfg.norm_eps
    )
    out = out.reshape(b, t, d) * jax.nn.silu(g)
    return jnp.einsum("btd,de->bte", out, lp["wo"]), state, x[:, -1]


def _channel_mix(lp, x, cfg, last_x):
    prev = _token_shift(x, last_x)
    xk = x + (prev - x) * lp["cm_mu_k"]
    xr = x + (prev - x) * lp["cm_mu_r"]
    kk = jnp.einsum("btd,df->btf", xk, lp["cm_wk"])
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("btf,fd->btd", kk, lp["cm_wv"])
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, lp["cm_wr"]))
    return rr * vv, x[:, -1]


def init_state(cfg: ArchConfig, batch: int, rules: MeshRules = NO_MESH):
    h, hd = cfg.num_heads, cfg.hd
    s = {
        "wkv": jnp.zeros((cfg.num_layers, batch, h, hd, hd), jnp.float32),
        "last_tm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), _dtype(cfg)),
        "last_cm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), _dtype(cfg)),
    }
    s["wkv"] = rules.constrain(s["wkv"], (None, "batch", "tp", None, None))
    return s


def state_logical(cfg: ArchConfig) -> dict:
    return {
        "wkv": (None, "batch", "tp", None, None),
        "last_tm": (None, "batch", None),
        "last_cm": (None, "batch", None),
    }


def forward(params, cfg: ArchConfig, tokens, *, state=None, rules=NO_MESH,
            chunk: int = 64, remat: bool = True, return_state: bool = False,
            last_only: bool = False):
    """Full-sequence forward (train/prefill). chunk = WKV chunk length."""
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = rules.constrain(x, ("batch", None, None))
    if state is None:
        state = init_state(cfg, b, rules)

    def body(x, xs):
        lp, wkv_s, ltm, lcm = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        tm, wkv_new, ltm_new = _time_mix(
            lp, h, cfg, wkv_s, ltm, chunk=chunk, rules=rules
        )
        x = x + tm.astype(x.dtype)
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        cm, lcm_new = _channel_mix(lp, h2, cfg, lcm)
        x = x + cm.astype(x.dtype)
        x = rules.constrain(x, ("batch", None, None))
        return x, (wkv_new, ltm_new.astype(ltm.dtype), lcm_new.astype(lcm.dtype))

    scan_body = jax.checkpoint(body) if remat else body
    x, (wkv, ltm, lcm) = jax.lax.scan(
        scan_body, x,
        (params["layers"], state["wkv"], state["last_tm"], state["last_cm"]),
    )
    if last_only:
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_state = {"wkv": wkv, "last_tm": ltm, "last_cm": lcm}
    if return_state:
        return logits, new_state
    return logits, jnp.zeros((), jnp.float32)


def prefill(params, cfg, tokens, max_len=None, *, rules=NO_MESH, chunk=64):
    logits, state = forward(
        params, cfg, tokens, rules=rules, chunk=chunk, remat=False,
        return_state=True, last_only=True,
    )
    return logits[:, -1], state


def decode_step(params, cfg, token, state, *, rules=NO_MESH):
    """O(1) recurrence — a single-token chunked call reuses the same code."""
    logits, new_state = forward(
        params, cfg, token[:, None], state=state, rules=rules, chunk=1,
        remat=False, return_state=True,
    )
    return logits[:, -1], new_state
