"""Decoder-only transformer covering the dense, MoE, sliding-window
(gemma3) and M-RoPE VLM (qwen2-vl) architectures.

Uniform pre-norm residual blocks; layers are stacked and scanned (compile
time / HLO size at 64+ layers). KV caches are (L, B, S, Kv, hd) stacked and
threaded through the same scan. Simplifications vs the public checkpoints
(uniform pre-norm, single rope theta, all-MoE layer stacks) are documented
in DESIGN.md section 6 — dimensions, head/expert structure and attention
patterns follow the assigned specs exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.sharding import MeshRules, NO_MESH


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------- params
def init_layer(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype)
    return p


def logical_layer(cfg: ArchConfig, ep: bool, attn_mode: str = "heads") -> dict:
    t = {
        "ln1": (None,),
        "attn": L.logical_attention(cfg, attn_mode),
        "ln2": (None,),
    }
    if cfg.moe is not None:
        t["moe"] = L.logical_moe(cfg, ep)
    else:
        t["mlp"] = L.logical_mlp(cfg)
    return t


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.init_embed(k_embed, cfg, dtype),
        "layers": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def logical_tree(cfg: ArchConfig, rules: MeshRules, *,
                 decode: bool = False) -> dict:
    ep = False
    if cfg.moe is not None and rules.mesh is not None:
        ep = cfg.moe.num_experts % rules.mesh.shape[rules.tensor] == 0
    mode = L.attn_shard_mode(cfg, rules, decode=decode)
    per_layer = logical_layer(cfg, ep, mode if mode != "seq" else "heads")
    if mode == "seq":
        # whole-layer sequence parallelism: layer weights are fsdp-only
        # (replicating a <=4B model's weights over the tensor axis is
        # cheap; activations carry the tensor axis on T instead)
        per_layer = jax.tree.map(
            lambda lg: tuple(None if a == "tp" else a for a in lg),
            per_layer,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
    # stacked layers gain a leading (replicated) layer dim
    stacked = jax.tree.map(
        lambda lg: (None, *lg),
        per_layer,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    return {
        "embed": L.logical_embed(cfg),
        "layers": stacked,
        "final_norm": (None,),
    }


def layer_windows(cfg: ArchConfig) -> jax.Array:
    """Per-layer attention window (0 = full/global). gemma3: 5 local : 1
    global — layer i is global iff (i+1) % global_every == 0."""
    idx = jnp.arange(cfg.num_layers)
    if cfg.attn_kind == "sliding":
        if cfg.global_every > 0:
            is_global = (idx + 1) % cfg.global_every == 0
            return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)
        return jnp.full((cfg.num_layers,), cfg.sliding_window, jnp.int32)
    return jnp.zeros((cfg.num_layers,), jnp.int32)


# ------------------------------------------------------------------- blocks
def _attn_block(lp, x, cfg, *, q_pos, k_cache, v_cache, kv_pos, window,
                pos3, rules, chunk, mode="heads"):
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(lp["attn"], h, cfg)
    if cfg.mrope and pos3 is not None:
        q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, q_pos, cfg.rope_theta)
        k = L.apply_rope(k, q_pos, cfg.rope_theta)
    qspec = {"heads": ("batch", None, "tp", None),
             "heads_repkv": ("batch", None, "tp", None),
             "hd": ("batch", None, None, "tp"),
             "seq": ("batch", "seq", None, None),
             "none": ("batch", None, None, None)}[mode]
    q = rules.constrain(q, qspec)
    k_new, v_new = k, v            # cache-bound KV: original kv heads
    if mode == "seq":
        # queries stay T-sharded; keys/values gather (GQA KV is small)
        k = rules.constrain(k, ("batch", None, None, None))
        v = rules.constrain(v, ("batch", None, None, None))
    elif mode == "heads_repkv":
        # expand GQA -> MHA so the head axis shards cleanly (grok: 8 kv
        # heads cannot split a 16-way axis; repeated KV shards with Q)
        g = cfg.num_heads // cfg.num_kv_heads
        k = rules.constrain(jnp.repeat(k, g, axis=2), qspec)
        v = rules.constrain(jnp.repeat(v, g, axis=2), qspec)
    else:
        k = rules.constrain(k, qspec)
        v = rules.constrain(v, qspec)
        k_new, v_new = k, v
    if k_cache is not None:                      # decode: attend to cache
        k_all, v_all, kv_p = k_cache, v_cache, kv_pos
    else:                                        # train/prefill: self k/v
        k_all, v_all, kv_p = k, v, q_pos
    o = L.chunked_attention(
        q, k_all, v_all, q_pos=q_pos, kv_pos=kv_p,
        causal=True, window=window, chunk=chunk, rules=rules,
    )
    return x + L.attention_out(lp["attn"], o), k_new, v_new


def _ffn_block(lp, x, cfg, rules):
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        out, aux = L.moe(lp["moe"], h, cfg, rules)
        return x + out, aux.load_balance_loss
    return x + L.mlp(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)


# ------------------------------------------------------------------ forward
def forward(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,                  # (B, T) int32
    *,
    positions: jax.Array | None = None,  # (B, T) absolute; default arange
    pos3: jax.Array | None = None,       # (3, B, T) for M-RoPE
    vision_embeds: jax.Array | None = None,  # (B, Tv, d) stub frontend
    rules: MeshRules = NO_MESH,
    chunk: int = 1024,
    remat: bool = True,
    collect_cache: bool = False,
    last_only: bool = False,
):
    """Full-sequence forward. Returns (logits, aux_loss[, (k_stack, v_stack)])."""
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    if vision_embeds is not None:
        tv = min(vision_embeds.shape[1], t)
        x = x.at[:, :tv, :].set(vision_embeds[:, :tv].astype(x.dtype))
    mode = L.attn_shard_mode(cfg, rules)
    xspec = ("batch", "seq", None) if mode == "seq" else ("batch", None, None)
    x = rules.constrain(x, xspec)
    q_pos = positions if positions is not None else jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[None, :], (b, t)
    )
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, window = xs
        x, k, v = _attn_block(
            lp, x, cfg, q_pos=q_pos, k_cache=None, v_cache=None, kv_pos=None,
            window=window, pos3=pos3, rules=rules, chunk=chunk, mode=mode,
        )
        x, lb = _ffn_block(lp, x, cfg, rules)
        x = rules.constrain(x, xspec)
        if collect_cache:
            # shard the emitted KV (kv heads, else head_dim, else seq):
            # grok's kv=8 < 16-way tensor axis would otherwise replicate
            # multi-GiB per-layer caches across the tensor axis
            from repro.models.sharding import kv_cache_axes
            kv_axes = kv_cache_axes(cfg.num_kv_heads, cfg.hd, rules)[1:]
            ys = (rules.constrain(k, kv_axes),
                  rules.constrain(v, kv_axes))
        else:
            ys = None
        return (x, aux + lb), ys

    scan_body = jax.checkpoint(body) if remat else body
    (x, aux), kv = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                (params["layers"], windows))
    if last_only:
        x = x[:, -1:]
    if mode == "seq":
        x = rules.constrain(x, ("batch", None, None))  # free T for vocab-tp
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    if collect_cache:
        return logits, aux, kv
    return logits, aux


# -------------------------------------------------------------------- cache
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               rules: MeshRules = NO_MESH, kv_dtype: str = "bf16"):
    from repro.models.sharding import kv_cache_axes
    kv, hd = cfg.num_kv_heads, cfg.hd
    dtype = jnp.int8 if kv_dtype == "int8" else _dtype(cfg)
    axes = kv_cache_axes(kv, hd, rules)
    k = jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype)
    v = jnp.zeros((cfg.num_layers, batch, max_len, kv, hd), dtype)
    k = rules.constrain(k, axes)
    v = rules.constrain(v, axes)
    cache = {
        "k": k,
        "v": v,
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }
    if kv_dtype == "int8":
        sc_axes = axes[:3] + (axes[3],)
        cache["k_scale"] = rules.constrain(
            jnp.zeros((cfg.num_layers, batch, max_len, kv), jnp.float16),
            sc_axes)
        cache["v_scale"] = rules.constrain(
            jnp.zeros((cfg.num_layers, batch, max_len, kv), jnp.float16),
            sc_axes)
    return cache


def cache_logical(cfg: ArchConfig, rules: MeshRules = NO_MESH,
                  kv_dtype: str = "bf16") -> dict:
    from repro.models.sharding import kv_cache_axes
    axes = kv_cache_axes(cfg.num_kv_heads, cfg.hd, rules)
    out = {
        "k": axes,
        "v": axes,
        "pos": ("batch", None),
        "idx": (),
    }
    if kv_dtype == "int8":
        out["k_scale"] = axes[:4]
        out["v_scale"] = axes[:4]
    return out


def prefill(params, cfg, tokens, max_len: int, *, rules=NO_MESH, chunk=1024,
            pos3=None, vision_embeds=None, kv_dtype: str = "bf16"):
    """Run the full prompt, build the cache. Returns (last_logits, cache)."""
    b, t = tokens.shape
    logits, _, (k_stack, v_stack) = forward(
        params, cfg, tokens, rules=rules, chunk=chunk, collect_cache=True,
        pos3=pos3, vision_embeds=vision_embeds, remat=False, last_only=True,
    )
    cache = init_cache(cfg, b, max_len, rules, kv_dtype=kv_dtype)
    if kv_dtype == "int8":
        k_stack, ks = jax.vmap(L.quantize_kv)(k_stack)
        v_stack, vs = jax.vmap(L.quantize_kv)(v_stack)
        cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks.astype(jnp.float16), (0, 0, 0, 0))
        cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs.astype(jnp.float16), (0, 0, 0, 0))
    # scan stacks ys on axis 0 -> (L, B, T, kv, hd)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_stack.astype(cache["k"].dtype), (0, 0, 0, 0, 0)
    )
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_stack.astype(cache["v"].dtype), (0, 0, 0, 0, 0)
    )
    cache["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"],
        jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t)),
        (0, 0),
    )
    cache["idx"] = jnp.array(t, jnp.int32)
    return logits[:, -1], cache


def decode_step(params, cfg, token, cache, *, rules=NO_MESH, chunk=4096,
                pos3=None, window_slice: bool = True):
    """One decode step. token: (B,) int32. Returns (logits, new_cache).

    For sliding-window layers (`window_slice=True`, gemma3), attention
    reads only the last `sliding_window` cache entries via a static-size
    dynamic slice instead of masking the full-length cache — at 500k
    context this drops per-step attention FLOPs/bytes by ~window/S for the
    29/34 local layers (EXPERIMENTS.md section Perf)."""
    b = token.shape[0]
    x = L.embed(params["embed"], token[:, None])
    q_pos = jnp.broadcast_to(cache["idx"][None, None], (b, 1)).astype(jnp.int32)
    windows = layer_windows(cfg)
    idx = cache["idx"]
    kv_pos_full = jax.lax.dynamic_update_slice(cache["pos"], q_pos, (0, idx))
    max_len = cache["k"].shape[2]
    w = cfg.sliding_window
    use_slicing = (window_slice and cfg.attn_kind == "sliding"
                   and w < max_len)

    dec_mode = L.attn_shard_mode(cfg, rules, decode=True)
    qspec = {"heads": ("batch", None, "tp", None),
             "hd": ("batch", None, None, "tp"),
             "none": ("batch", None, None, None)}[dec_mode]
    quantized = "k_scale" in cache

    def attn(lp, x, k_c, v_c, window, sliced: bool, ks_c=None, vs_c=None):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], h, cfg)
        q = rules.constrain(q, qspec)
        if cfg.mrope and pos3 is not None:
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, q_pos, cfg.rope_theta)
            k = L.apply_rope(k, q_pos, cfg.rope_theta)
        if quantized:
            k, ksc = L.quantize_kv(k)
            v, vsc = L.quantize_kv(v)
            ks_c = jax.lax.dynamic_update_slice(
                ks_c, ksc.astype(ks_c.dtype), (0, idx, 0))
            vs_c = jax.lax.dynamic_update_slice(
                vs_c, vsc.astype(vs_c.dtype), (0, idx, 0))
        k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype),
                                           (0, idx, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype),
                                           (0, idx, 0, 0))
        ks_at = vs_at = None
        if sliced:
            start = jnp.maximum(idx - (w - 1), 0)
            k_at = jax.lax.dynamic_slice_in_dim(k_c, start, w, axis=1)
            v_at = jax.lax.dynamic_slice_in_dim(v_c, start, w, axis=1)
            kv_p = jax.lax.dynamic_slice_in_dim(kv_pos_full, start, w, axis=1)
            if quantized:
                ks_at = jax.lax.dynamic_slice_in_dim(ks_c, start, w, axis=1)
                vs_at = jax.lax.dynamic_slice_in_dim(vs_c, start, w, axis=1)
        else:
            k_at, v_at, kv_p = k_c, v_c, kv_pos_full
            if quantized:
                ks_at, vs_at = ks_c, vs_c
        o = L.chunked_attention(
            q, k_at, v_at, q_pos=q_pos, kv_pos=kv_p, causal=True,
            window=window, chunk=chunk, rules=rules,
            k_scale=ks_at, v_scale=vs_at,
        )
        x = x + L.attention_out(lp["attn"], o)
        x, _ = _ffn_block(lp, x, cfg, rules)
        return x, k_c, v_c, ks_c, vs_c

    if not use_slicing:
        if quantized:
            def body(carry, xs):
                x = carry
                lp, window, k_c, v_c, ks_c, vs_c = xs
                x, k_c, v_c, ks_c, vs_c = attn(
                    lp, x, k_c, v_c, window, sliced=False,
                    ks_c=ks_c, vs_c=vs_c)
                return x, (k_c, v_c, ks_c, vs_c)

            x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, x, (params["layers"], windows, cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
        else:
            def body(carry, xs):
                x = carry
                lp, window, k_c, v_c = xs
                x, k_c, v_c, _, _ = attn(lp, x, k_c, v_c, window,
                                         sliced=False)
                return x, (k_c, v_c)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], windows, cache["k"], cache["v"]))
    else:
        # block structure: contiguous runs of local (windowed) layers are
        # scanned with sliced caches; global layers run individually with
        # the full cache.
        ge = cfg.global_every
        is_global = [ge > 0 and (i + 1) % ge == 0
                     for i in range(cfg.num_layers)]
        k_new = cache["k"]
        v_new = cache["v"]

        def local_block(x, lo, hi):
            seg = jax.tree.map(lambda a: a[lo:hi], params["layers"])

            def body(carry, xs):
                x = carry
                lp, k_c, v_c = xs
                x, k_c, v_c, _, _ = attn(lp, x, k_c, v_c,
                                         jnp.asarray(w, jnp.int32),
                                         sliced=True)
                return x, (k_c, v_c)

            x, (k_seg, v_seg) = jax.lax.scan(
                body, x, (seg, k_new[lo:hi], v_new[lo:hi]))
            return x, k_seg, v_seg

        i = 0
        while i < cfg.num_layers:
            if is_global[i]:
                lp = jax.tree.map(lambda a: a[i], params["layers"])
                x, k_i, v_i, _, _ = attn(lp, x, k_new[i], v_new[i],
                                         jnp.asarray(0, jnp.int32),
                                         sliced=False)
                k_new = k_new.at[i].set(k_i)
                v_new = v_new.at[i].set(v_i)
                i += 1
            else:
                j = i
                while j < cfg.num_layers and not is_global[j]:
                    j += 1
                x, k_seg, v_seg = local_block(x, i, j)
                k_new = jax.lax.dynamic_update_slice_in_dim(
                    k_new, k_seg, i, axis=0)
                v_new = jax.lax.dynamic_update_slice_in_dim(
                    v_new, v_seg, i, axis=0)
                i = j

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_new, v_new
    if quantized and not use_slicing:
        new_cache["k_scale"], new_cache["v_scale"] = ks_new, vs_new
    new_cache["pos"] = kv_pos_full
    new_cache["idx"] = idx + 1
    return logits, new_cache
