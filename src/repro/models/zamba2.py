"""Zamba2 — Mamba2 backbone with a single *shared* attention block applied
every `shared_attn_every` layers.

The shared block (one set of weights, ~13 application points at 81 layers)
takes concat(hidden, initial_embedding) fused to width d by a small
per-application adapter (Zamba2's unshared LoRA adapters, simplified to one
dense per application), then runs a standard attention + MLP block with its
own KV cache slot per application point. See DESIGN.md section 6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.models.sharding import MeshRules, NO_MESH


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def num_shared_points(cfg: ArchConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_every


def init_params(key, cfg: ArchConfig) -> dict:
    dtype = _dtype(cfg)
    d = cfg.d_model
    npts = num_shared_points(cfg)
    k_embed, k_layers, k_shared, k_adapt = jax.random.split(key, 4)
    stacked = jax.vmap(lambda k: mamba2.init_layer(k, cfg, dtype))(
        jax.random.split(k_layers, cfg.num_layers)
    )
    ks = jax.random.split(k_shared, 2)
    shared = {
        "ln1": jnp.zeros((d,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "ln2": jnp.zeros((d,), dtype),
        "mlp": L.init_mlp(ks[1], cfg, dtype),
    }
    adapters = jax.vmap(
        lambda k: L._dense_init(k, (2 * d, d), 2 * d, dtype)
    )(jax.random.split(k_adapt, npts))
    return {
        "embed": L.init_embed(k_embed, cfg, dtype),
        "layers": stacked,
        "shared": shared,
        "adapters": adapters,           # (npts, 2d, d)
        "final_norm": jnp.zeros((d,), dtype),
    }


def logical_tree(cfg: ArchConfig, rules: MeshRules) -> dict:
    per_layer = mamba2.logical_layer(cfg)
    stack = lambda tree: jax.tree.map(
        lambda lg: (None, *lg), tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
    return {
        "embed": L.logical_embed(cfg),
        "layers": stack(per_layer),
        "shared": {
            "ln1": (None,),
            "attn": L.logical_attention(cfg, L.attn_shard_mode(cfg, rules)),
            "ln2": (None,),
            "mlp": L.logical_mlp(cfg),
        },
        "adapters": (None, "d", "tp"),
        "final_norm": (None,),
    }


# -------------------------------------------------------------------- cache
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               rules: MeshRules = NO_MESH):
    npts = num_shared_points(cfg)
    kv, hd = cfg.num_kv_heads, cfg.hd
    dtype = _dtype(cfg)
    c = {
        "mamba": mamba2.init_state(cfg, batch, cfg.num_layers, rules, dtype),
        "k": jnp.zeros((npts, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((npts, batch, max_len, kv, hd), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }
    from repro.models.sharding import kv_cache_axes
    axes = kv_cache_axes(kv, hd, rules)
    c["k"] = rules.constrain(c["k"], axes)
    c["v"] = rules.constrain(c["v"], axes)
    return c


def cache_logical(cfg: ArchConfig, rules: MeshRules = NO_MESH) -> dict:
    from repro.models.sharding import kv_cache_axes
    axes = kv_cache_axes(cfg.num_kv_heads, cfg.hd, rules)
    return {
        "mamba": mamba2.state_logical(cfg),
        "k": axes,
        "v": axes,
        "pos": ("batch", None),
        "idx": (),
    }


def _shared_block(params, pt_idx, x, x0, cfg, *, q_pos, cache_k, cache_v,
                  kv_pos, write_idx, rules, chunk):
    """Apply the shared attention block at application point pt_idx.
    cache_k/v: (B, S, kv, hd) slices or None (train). Returns
    (x_new, k_new, v_new) where k/v are this segment's keys/values."""
    sp = params["shared"]
    adapter = params["adapters"][pt_idx]
    h = jnp.einsum("btd,de->bte", jnp.concatenate([x, x0], axis=-1), adapter)
    hn = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
    q, k, v = L.attention_qkv(sp["attn"], hn, cfg)
    q = L.apply_rope(q, q_pos, cfg.rope_theta)
    k = L.apply_rope(k, q_pos, cfg.rope_theta)
    if cache_k is not None:
        k_all = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, write_idx, 0, 0))
        v_all = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, write_idx, 0, 0))
        kv_p = kv_pos
    else:
        k_all, v_all, kv_p = k, v, q_pos
    o = L.chunked_attention(q, k_all, v_all, q_pos=q_pos, kv_pos=kv_p,
                            causal=True, chunk=chunk, rules=rules)
    h = h + L.attention_out(sp["attn"], o)
    h = h + L.mlp(sp["mlp"], L.rms_norm(h, sp["ln2"], cfg.norm_eps), cfg)
    if cache_k is not None:
        return x + h, k_all, v_all
    return x + h, k, v


def forward(params, cfg: ArchConfig, tokens, *, cache=None, rules=NO_MESH,
            ssm_chunk: int = 64, attn_chunk: int = 1024, remat: bool = True,
            return_cache: bool = False, last_only: bool = False):
    """Full-sequence forward; threads mamba states and (optionally) builds
    the shared-attention KV caches for decode."""
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = rules.constrain(x, ("batch", None, None))
    x0 = x
    fresh = cache is None
    if fresh:
        cache = init_cache(cfg, b, t, rules)
    idx = cache["idx"]
    q_pos = idx[None, None] + jnp.broadcast_to(
        jnp.arange(t, dtype=jnp.int32)[None, :], (b, t))
    kv_pos = jax.lax.dynamic_update_slice(cache["pos"], q_pos, (0, idx))

    every = cfg.shared_attn_every
    npts = num_shared_points(cfg)
    mstate = cache["mamba"]

    def mamba_seg(x, lo: int, hi: int, remat_flag: bool):
        """Scan mamba layers [lo, hi) with their states."""
        seg_params = jax.tree.map(lambda a: a[lo:hi], params["layers"])
        seg_state = jax.tree.map(lambda a: a[lo:hi], mstate)

        def body(x, xs):
            lp, st = xs
            out, st_new = mamba2.block(lp, x, cfg, st, chunk=ssm_chunk,
                                       rules=rules)
            x = rules.constrain(x + out, ("batch", None, None))
            return x, st_new

        fn = jax.checkpoint(body) if remat_flag else body
        x, seg_new = jax.lax.scan(fn, x, (seg_params, seg_state))
        return x, seg_new

    new_mamba_segs = []
    k_new = cache["k"]
    v_new = cache["v"]
    for p in range(npts):
        x, seg_state = mamba_seg(x, p * every, (p + 1) * every, remat)
        new_mamba_segs.append(seg_state)
        x, k_p, v_p = _shared_block(
            params, p, x, x0, cfg, q_pos=q_pos,
            cache_k=None if fresh and not return_cache else cache["k"][p],
            cache_v=None if fresh and not return_cache else cache["v"][p],
            kv_pos=kv_pos, write_idx=idx, rules=rules, chunk=attn_chunk,
        )
        if return_cache or not fresh:
            k_new = k_new.at[p].set(k_p)
            v_new = v_new.at[p].set(v_p)
    if npts * every < cfg.num_layers:                    # trailing layers
        x, seg_state = mamba_seg(x, npts * every, cfg.num_layers, remat)
        new_mamba_segs.append(seg_state)

    if last_only:
        x = x[:, -1:]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache = {
        "mamba": jax.tree.map(
            lambda *segs: jnp.concatenate(segs, axis=0), *new_mamba_segs
        ),
        "k": k_new, "v": v_new,
        "pos": kv_pos,
        "idx": idx + t,
    }
    if return_cache:
        return logits, new_cache
    return logits, jnp.zeros((), jnp.float32)


def prefill(params, cfg, tokens, max_len: int, *, rules=NO_MESH,
            ssm_chunk=64, attn_chunk=1024):
    b, t = tokens.shape
    cache = init_cache(cfg, b, max_len, rules)
    logits, cache = forward(
        params, cfg, tokens, cache=cache, rules=rules, ssm_chunk=ssm_chunk,
        attn_chunk=attn_chunk, remat=False, return_cache=True, last_only=True,
    )
    return logits[:, -1], cache


def decode_step(params, cfg, token, cache, *, rules=NO_MESH,
                attn_chunk: int = 4096):
    logits, cache = forward(
        params, cfg, token[:, None], cache=cache, rules=rules, ssm_chunk=1,
        attn_chunk=attn_chunk, remat=False, return_cache=True,
    )
    return logits[:, -1], cache
