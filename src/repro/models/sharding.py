"""Logical-axis sharding rules.

Every parameter/activation dimension carries a *logical* axis name; the
MeshRules translate logical names to mesh axes, silently replicating any
dimension the mesh cannot divide evenly (e.g. smollm's 15 heads on a
16-way tensor axis fall back to head_dim sharding at the einsum level).

Logical names:
  "d"      — model width (FSDP-sharded over the data/pod axes)
  "tp"     — tensor-parallel dim (heads / ffn / vocab / experts / head_dim)
  "batch"  — activation batch (data/pod axes)
  "seq"    — activation sequence (tensor axis; long-context decode caches)
  None     — replicated
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Mesh | None = None
    fsdp: tuple[str, ...] = ("data",)
    tensor: str = "model"

    def _axes_for(self, logical: str | None):
        if logical in ("d", "batch"):
            return self.fsdp
        if logical in ("tp", "seq"):
            return (self.tensor,)
        if logical is None:
            return None
        raise ValueError(f"unknown logical axis {logical!r}")

    def _axis_size(self, axes: tuple[str, ...]) -> int:
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def spec(self, logical: tuple, shape: tuple) -> P:
        """PartitionSpec for `shape`, dropping non-divisible dims."""
        if self.mesh is None:
            return P()
        parts = []
        used: set[str] = set()
        for name, dim in zip(logical, shape):
            axes = self._axes_for(name)
            if (
                axes is None
                or any(a in used for a in axes)
                or dim % self._axis_size(axes) != 0
            ):
                parts.append(None)
            else:
                parts.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
        return P(*parts)

    def sharding(self, logical: tuple, shape: tuple) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x: jax.Array, logical: tuple) -> jax.Array:
        """with_sharding_constraint by logical names (no-op off-mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical, x.shape))
        )


# Default CPU/test rules: no mesh, everything replicated, constraints no-op.
NO_MESH = MeshRules(mesh=None)


def _is_logical_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_specs(rules: MeshRules, params, logical_tree):
    """Map a params tree + matching logical tree -> PartitionSpec tree.

    Logical leaves are tuples of axis names (one per array dim; () for
    scalars); params trees are nested dicts of arrays with an identical
    structure.
    """
    return jax.tree.map(
        lambda logical, arr: rules.spec(tuple(logical), arr.shape),
        logical_tree,
        params,
        is_leaf=_is_logical_leaf,
    )


def tree_shardings(rules: MeshRules, params, logical_tree):
    if rules.mesh is None:
        return None
    return jax.tree.map(
        lambda logical, arr: NamedSharding(
            rules.mesh, rules.spec(tuple(logical), arr.shape)
        ),
        logical_tree,
        params,
        is_leaf=_is_logical_leaf,
    )


def tree_constrain(rules: MeshRules, tree, logical_tree):
    """with_sharding_constraint over a whole tree by logical names."""
    if rules.mesh is None:
        return tree
    return jax.tree.map(
        lambda logical, arr: rules.constrain(arr, tuple(logical)),
        logical_tree,
        tree,
        is_leaf=_is_logical_leaf,
    )


def kv_cache_axes(num_kv_heads: int, head_dim: int, rules: MeshRules):
    """Pick the tensor-sharded dim of a (L, B, S, kv, hd) KV cache.

    Prefer kv heads, then head_dim, then sequence. kv/hd sharding keeps the
    S axis unsharded so dynamic window slices and cache writes never force
    an SPMD gather (the seq fallback is only ever hit off-mesh)."""
    if rules.mesh is None:
        return (None, "batch", None, None, None)
    ts = rules.mesh.shape[rules.tensor]
    if num_kv_heads % ts == 0:
        return (None, "batch", None, "tp", None)
    if head_dim % ts == 0:
        return (None, "batch", None, None, "tp")
    return (None, "batch", "seq", None, None)
