"""Model zoo: dense/MoE transformers, whisper enc-dec, RWKV6, Mamba2/Zamba2
hybrid, Qwen2-VL backbone. Pure-functional JAX; scan-over-layers; chunked
online-softmax attention (lowers on any backend with O(T*chunk) memory)."""
