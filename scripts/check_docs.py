"""Docs link-and-snippet check.

1. Executes every ```python code block in README.md and docs/*.md top to
   bottom (one shared namespace per file), so the quickstarts and the
   engine-guide walkthroughs keep running exactly as written.
2. Verifies that every repo path (src/..., benchmarks/..., examples/...,
   tests/..., docs/...) referenced in README.md and docs/*.md exists.
3. Verifies that every dotted `repro.*` module reference resolves to a
   real module file or package under src/.
4. Runs the executor quickstart `examples/jax_sweep.py` as a subprocess,
   so the README's backend walkthrough cannot rot.

Run from the repo root (CI does):  python scripts/check_docs.py
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src"

PATH_RE = re.compile(
    r"\b(?:src|benchmarks|examples|tests|docs)/[A-Za-z0-9_\-./*]*[A-Za-z0-9_*]"
)
MODULE_RE = re.compile(r"\brepro(?:\.[a-z0-9_]+)+\b")
CODE_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def doc_files() -> list[Path]:
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def check_paths() -> list[str]:
    errors = []
    for doc in doc_files():
        text = doc.read_text()
        for ref in sorted(set(PATH_RE.findall(text))):
            ref = ref.rstrip(".")
            if "*" in ref:
                if not any(ROOT.glob(ref)):
                    errors.append(f"{doc.name}: glob {ref!r} matches nothing")
            elif not (ROOT / ref).exists():
                errors.append(f"{doc.name}: missing path {ref!r}")
    return errors


def module_resolves(dotted: str) -> bool:
    """True if some prefix of `dotted` (>= 2 segments) is a module/package;
    trailing segments are assumed to be attributes of it."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        p = SRC.joinpath(*parts[:end])
        if p.with_suffix(".py").exists() or (p / "__init__.py").exists():
            return True
    return False


def check_modules() -> list[str]:
    errors = []
    for doc in doc_files():
        text = doc.read_text()
        for ref in sorted(set(MODULE_RE.findall(text))):
            if not module_resolves(ref):
                errors.append(f"{doc.name}: unresolvable module {ref!r}")
    return errors


def run_doc_snippets() -> list[str]:
    sys.path.insert(0, str(SRC))
    errors = []
    for doc in doc_files():
        text = doc.read_text()
        namespace: dict = {"__name__": f"__{doc.stem}__"}
        for i, block in enumerate(CODE_BLOCK_RE.findall(text), 1):
            print(f"-- executing {doc.name} python block {i} "
                  f"({len(block.splitlines())} lines)")
            try:
                exec(compile(block, f"{doc.name}:block{i}", "exec"), namespace)
            except Exception as e:  # noqa: BLE001 - report, don't crash
                errors.append(f"{doc.name} python block {i} failed: {e!r}")
    return errors


# example scripts doubling as executable documentation (README refers to
# them); each runs in a subprocess with src/ on the path
EXAMPLE_SCRIPTS = ("examples/jax_sweep.py",)


def run_example_scripts() -> list[str]:
    import os

    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for rel in EXAMPLE_SCRIPTS:
        print(f"-- running {rel}")
        try:
            proc = subprocess.run([sys.executable, str(ROOT / rel)],
                                  env=env, cwd=ROOT, capture_output=True,
                                  text=True, timeout=600)
        except subprocess.TimeoutExpired:
            errors.append(f"{rel} timed out after 600s")
            continue
        if proc.returncode != 0:
            errors.append(f"{rel} exited {proc.returncode}: "
                          f"{proc.stderr.strip()[-400:]}")
    return errors


def main() -> int:
    errors = check_paths() + check_modules()
    errors += run_doc_snippets()
    errors += run_example_scripts()
    if errors:
        print("\n".join(f"ERROR: {e}" for e in errors))
        return 1
    print("docs check OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
