"""Scenario-sweep walkthrough: Monte-Carlo evaluation of the paper's claim.

Samples 40 repair scenarios (codes, cluster sizes, volatility regimes,
correlated failures), runs every applicable scheme on each via the batched
sweep engine, and prints per-scheme distributions plus the BMF-vs-PPR and
MSRepair-vs-mPPR speedup CDFs — the statistical version of paper
Figs. 9/10.

    PYTHONPATH=src python examples/sweep_demo.py
"""
from repro.sim import MonteCarloSuite, SampleSpace, TraceSuite, run_sweep


def main():
    space = SampleSpace(
        codes=((4, 2), (6, 3), (7, 4)),
        cluster_sizes=(10, 14),
        chunk_mb=(8.0, 32.0),
        regimes=("cold5s", "hot2s", "wan_drift"),
        failure_patterns=("single", "double", "rack"),
    )
    suite = MonteCarloSuite("demo", 40, space, base_seed=7)
    print(f"== sweeping {len(suite)} Monte-Carlo scenarios ==")
    sweep = run_sweep(suite)

    print("\nper-scheme repair-time distributions:")
    print(sweep.summary_table())

    for base, scheme in (("ppr", "bmf"), ("mppr", "msrepair")):
        spd = sweep.speedups(base, scheme)
        if not len(spd):
            continue
        print(f"\n{scheme} vs {base}: mean reduction "
              f"{sweep.reduction_pct(base, scheme):.1f}% over {len(spd)} "
              f"paired scenarios")
        for q in (10, 50, 90):
            print(f"  speedup p{q:02d} = "
                  f"{sweep.speedup_percentile(base, scheme, q):.2f}x")

    # trace replay: freeze the bandwidth sample paths and re-run — results
    # are reproducible epoch-for-epoch, the A/B substrate for new planners
    frozen = TraceSuite.freeze(suite, num_epochs=64)
    sweep2 = run_sweep(frozen)
    print(f"\ntrace-replay sweep over the same {len(frozen)} scenarios:")
    print(sweep2.summary_table())


if __name__ == "__main__":
    main()
