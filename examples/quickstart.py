"""Quickstart: train a ~reduced LM for 120 steps with erasure-coded
checkpointing, lose two failure domains mid-run, repair with MSRepair, and
resume — all on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ECCheckpointConfig, ECCheckpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.data.pipeline import SyntheticStream
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def main():
    cfg = get_arch("smollm_360m").reduced()
    shape = ShapeConfig("quickstart", "train", 64, 8)
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=5e-3, warmup_steps=10),
                       microbatches=2, attn_chunk=32)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_quickstart_")
    _, bwm = topology.tpu_pod_dcn_matrix(8, 1)
    ck = ECCheckpointer(
        ECCheckpointConfig(directory=ckpt_dir, n=6, k=4,
                           chunk_bytes=1 << 16, num_domains=8,
                           scheme="msrepair", single_scheme="bmf"),
        bw=BandwidthProcess(base=bwm, change_interval=2.0, mode="markov"),
        ingress=IngressModel(),
    )

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    stream = SyntheticStream(cfg, shape)

    print(f"== training {cfg.name} (reduced) for 120 steps ==")
    for step in range(120):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, m = step_fn(state, batch)
        if step % 20 == 0:
            print(f"  step {step:3d}  loss {float(m['loss']):.4f}")
        if step == 60:
            ck.save(60, state, wait=True)
            print("  [ckpt] erasure-coded checkpoint written at step 60 "
                  f"(RS({ck.code.n},{ck.code.k}), 8 failure domains)")

    print("== simulating loss of domains {1, 5} and restoring ==")
    restored, report = ck.load(state, lost_domains=(1, 5))
    print(f"  repaired {report.blocks_repaired} blocks across "
          f"{report.stripes_repaired} stripes")
    if report.sim:
        print(f"  {report.sim.scheme} repair schedule: "
              f"{report.sim.num_rounds} rounds, "
              f"{report.sim.total_time:.3f}s simulated network time")
    restored_step = int(np.asarray(restored['step']))
    print(f"  restored train state at step {restored_step} — resuming")
    batch = {k: jnp.asarray(v)
             for k, v in stream.batch_at(restored_step).items()}
    _, m = step_fn(restored, batch)
    print(f"  resumed loss {float(m['loss']):.4f}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("done.")


if __name__ == "__main__":
    main()
