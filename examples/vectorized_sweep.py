"""Vectorized sweep walkthrough: the batched array engine end to end.

1. compiles a repair plan to its structure-of-arrays form and back,
2. runs the same Monte-Carlo suite under the serial (object) engine and
   the vectorized (batched array) executor and checks they agree,
3. times both on an execution-bound trace-frozen suite, where batching
   pays most,
4. times both on a planner-bound Table II-style suite (multi-node
   scheduling dominates): since the array-native planner layer landed —
   batched MSRepair scheduling, batched plan lowering, in-stepper BMF
   replanning — these suites vectorize too instead of pinning at serial
   speed.

    PYTHONPATH=src python examples/vectorized_sweep.py
"""
import time

from repro.core.engine import compile_plan, decompile
from repro.core.msrepair import plan_msrepair, select_helpers_multi
from repro.core.plan import Job
from repro.sim import MonteCarloSuite, SampleSpace, TraceSuite, run_sweep


def show_plan_compilation():
    helpers = select_helpers_multi(7, 4, [0, 1])
    jobs = [Job(job_id=i, failed_node=f, requestor=f, helpers=helpers[i])
            for i, f in enumerate((0, 1))]
    plan = plan_msrepair(jobs)
    pa = compile_plan(plan)
    print(f"plan: {pa.num_jobs} jobs, {pa.num_rounds} rounds, "
          f"{pa.num_transfers} transfers")
    print(f"  round offsets   {pa.round_start.tolist()}")
    print(f"  term bitmasks   {[hex(int(m)) for m in pa.t_terms]}")
    assert decompile(pa) == plan, "compile/decompile must round-trip exactly"
    print("  decompile(compile_plan(plan)) == plan  ✓")


def sweep_parity():
    space = SampleSpace(
        codes=((6, 3), (7, 4)), cluster_sizes=(10,), chunk_mb=(8.0,),
        regimes=("hot2s",), failure_patterns=("single", "double"),
    )
    suite = MonteCarloSuite("demo", 24, space, base_seed=3)
    serial = run_sweep(suite, executor="serial")
    vec = run_sweep(suite, executor="vectorized")
    worst = max(
        abs(cs.results[s].total_time - cv.results[s].total_time)
        / cs.results[s].total_time
        for cs, cv in zip(serial.cases, vec.cases) for s in cs.results
    )
    print(f"\n24-case sweep, serial vs vectorized: max relative "
          f"difference = {worst:.2e}")
    print(vec.summary_table())


def throughput():
    space = SampleSpace(
        codes=((14, 10),), cluster_sizes=(14,), chunk_mb=(512.0,),
        regimes=("hot2s",), failure_patterns=("single",),
    )
    live = MonteCarloSuite("stress", 40, space,
                           schemes=("traditional", "ppr"), base_seed=17)
    frozen = TraceSuite.freeze(live, num_epochs=256)
    timings = {}
    for executor in ("serial", "vectorized"):
        t0 = time.perf_counter()
        run_sweep(frozen, executor=executor)
        timings[executor] = time.perf_counter() - t0
    print(f"\nexecution-bound 40-case suite: "
          f"serial {timings['serial']:.2f}s, "
          f"vectorized {timings['vectorized']:.2f}s "
          f"({timings['serial'] / timings['vectorized']:.1f}x)")


def planner_bound_throughput():
    """Table II-style suite: RS(7,4) double failures, hot churn — almost
    all wall-clock is multi-node scheduling, the planner layer's turf."""
    space = SampleSpace(
        codes=((7, 4),), cluster_sizes=(14,), chunk_mb=(32.0,),
        regimes=("hot2s",), failure_patterns=("double",),
    )
    suite = MonteCarloSuite("table2ish", 60, space,
                            schemes=("mppr", "random", "msrepair"),
                            base_seed=0)
    frozen = TraceSuite.freeze(suite, num_epochs=64)
    timings = {}
    for executor in ("serial", "vectorized"):
        t0 = time.perf_counter()
        run_sweep(frozen, executor=executor)
        timings[executor] = time.perf_counter() - t0
    print(f"\nplanner-bound 60-case Table II suite: "
          f"serial {timings['serial'] * 1e3:.0f}ms, "
          f"vectorized {timings['vectorized'] * 1e3:.0f}ms "
          f"({timings['serial'] / timings['vectorized']:.1f}x — batched "
          f"planning, not just batched execution)")


def main():
    show_plan_compilation()
    sweep_parity()
    throughput()
    planner_bound_throughput()


if __name__ == "__main__":
    main()
