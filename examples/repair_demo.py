"""Repair-scheme walkthrough on the paper's own topologies.

Shows, for one failure on the measured Aliyun ECS matrix (paper Table III)
under hot churn: the plans traditional / PPR / PPT / BMFRepair produce,
their simulated repair times, and the actual byte-verified data-plane
execution of the BMF plan with the GF(256) Pallas kernels.

    PYTHONPATH=src python examples/repair_demo.py
"""
import numpy as np

from repro.core import executor, topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario
from repro.ec.rs import RSCode


def main():
    cluster, bw = topology.aliyun_matrix()
    code = RSCode(6, 3)
    bwp = BandwidthProcess(base=bw, change_interval=2.0, mode="markov",
                           sigma=1.0, rho=0.9, seed=15)
    sc = Scenario(num_nodes=6, code=code, failed=(0,), bw=bwp,
                  ingress=IngressModel(seed=15, duplex=0.5), chunk_mb=128)
    sim = RepairSimulator(sc)

    print(f"== repairing {cluster.name(0)}'s block, RS(6,3), 128 MB, "
          "Aliyun Table III bandwidths, hot churn ==")
    results = {}
    for scheme in ("traditional", "ppr", "ppt", "bmf"):
        r = sim.run(scheme)
        results[scheme] = r
        print(f"\n-- {scheme}: {r.total_time:.2f}s over {r.num_rounds} "
              f"round(s), planning {r.planning_time * 1e3:.2f} ms")
        if r.plan:
            for i, rnd in enumerate(r.plan.rounds):
                desc = ", ".join(
                    "->".join(cluster.name(x) for x in t.path)
                    for t in rnd.transfers)
                print(f"   round {i + 1}: {desc}")
        for line in r.log:
            print("   " + line)

    bmf, ppr = results["bmf"], results["ppr"]
    print(f"\nBMFRepair vs PPR: {100 * (1 - bmf.total_time / ppr.total_time):.1f}% "
          f"faster (paper: ~15.9% avg on Aliyun)")

    print("\n== executing the BMF plan on real data (GF(256) kernels) ==")
    rng = np.random.default_rng(0)
    data = rng.integers(0, 256, size=(3, 1 << 16), dtype=np.uint8)
    cw = code.encode(data)
    ex = executor.execute_plan(bmf.plan, code, cw)
    print(f"  reconstructed {ex.reconstructed[0].nbytes} bytes, "
          f"byte-exact: {ex.verified}, network bytes moved: {ex.bytes_moved}")


if __name__ == "__main__":
    main()
