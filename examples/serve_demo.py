"""Batched serving: prefill a prompt batch, decode greedily with KV caches
across three model families (transformer / RWKV6 state / zamba2 hybrid).

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import model as M
from repro.serve.serve_step import generate


def main():
    for arch in ("qwen2_15b", "rwkv6_16b", "zamba2_7b"):
        cfg = get_arch(arch).reduced()
        key = jax.random.PRNGKey(0)
        params = M.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (4, 24), 0,
                                              cfg.vocab_size)}
        t0 = time.time()
        out = generate(params, cfg, batch, steps=16, chunk=16)
        dt = time.time() - t0
        print(f"{arch:12s} generated {out.shape[0]}x{out.shape[1]} tokens "
              f"in {dt:5.1f}s — sample: {out[0, :8].tolist()}")


if __name__ == "__main__":
    main()
