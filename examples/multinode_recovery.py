"""Multi-node failure during training: two hosts die at once; MSRepair
schedules the parallel reconstruction (vs m-PPR serialization), training
elastically resumes. Also demos the straggler monitor.

    PYTHONPATH=src python examples/multinode_recovery.py
"""
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ECCheckpointConfig, ECCheckpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario
from repro.data.pipeline import SyntheticStream
from repro.ft import FailureInjector, StragglerMonitor
from repro.ft.failures import FailureEvent
from repro.ft.elastic import elastic_data_size
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def main():
    cfg = get_arch("qwen2_15b").reduced()
    shape = ShapeConfig("demo", "train", 32, 16)
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=5e-3, warmup_steps=5),
                       attn_chunk=16)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_multinode_")
    _, bwm = topology.tpu_pod_dcn_matrix(8, 2)          # 16 hosts, 2 pods
    ck = ECCheckpointer(
        ECCheckpointConfig(directory=ckpt_dir, n=7, k=4, chunk_bytes=1 << 15,
                           num_domains=16, scheme="msrepair"),
        bw=BandwidthProcess(base=bwm, change_interval=2.0, mode="markov"),
        ingress=IngressModel(),
    )
    injector = FailureInjector(
        num_domains=16,
        scheduled=(FailureEvent(step=25, domains=(2, 9)),))
    monitor = StragglerMonitor(num_hosts=16)

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    stream = SyntheticStream(cfg, shape)
    hosts = 16

    step = 0
    handled: set[int] = set()
    while step < 40:
        ev = injector.check(step)
        if ev is not None and step in handled:
            ev = None                       # dead hosts were already replaced
        if ev is not None:
            handled.add(step)
            print(f"\n!! step {step}: hosts {ev.domains} died")
            # price the multi-node repair with MSRepair vs m-PPR
            sc = Scenario(num_nodes=16, code=ck.code, failed=(0, 1),
                          bw=ck.bw, ingress=ck.ingress, chunk_mb=32)
            sim = RepairSimulator(sc)
            t_ms = sim.run("msrepair").total_time
            t_mp = sim.run("mppr").total_time
            print(f"   stripe repair schedule: msrepair {t_ms:.2f}s vs "
                  f"m-ppr {t_mp:.2f}s ({100 * (1 - t_ms / t_mp):.0f}% faster)")
            state, report = ck.load(state, lost_domains=ev.domains)
            print(f"   checkpoint repaired: {report.blocks_repaired} blocks, "
                  f"byte-verified")
            hosts -= len(ev.domains)
            new_batch = elastic_data_size(shape.global_batch, 16, hosts)
            print(f"   elastic re-mesh: {hosts} hosts remain, global batch "
                  f"{shape.global_batch} -> {new_batch}")
            step = int(np.asarray(state["step"]))
            continue
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, m = step_fn(state, batch)
        monitor.record(step % hosts, time.time() - t0)
        if step % 10 == 0:
            print(f"step {step:3d} loss {float(m['loss']):.4f} "
                  f"({hosts} hosts)")
        if step and step % 10 == 0:
            ck.save(step, state, wait=True)
        step += 1
    stragglers = monitor.stragglers()
    print(f"\nstraggler report: {stragglers or 'none flagged'}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("done.")


if __name__ == "__main__":
    main()
