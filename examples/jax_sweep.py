"""JAX executor quickstart: the jit device steppers end to end.

1. runs one Monte-Carlo suite under the serial (object) engine and
   `executor="jax"` (the jit `lax.while_loop`/`scan` steppers of
   `repro.core.engine.jax_stepper`) and checks they agree,
2. times the numpy vectorized executor against the jax executor on an
   execution-bound trace-frozen suite — the jax rows include compile
   time on the first run; the point of the backend is that the same
   compiled programs run unchanged on an accelerator,
3. shows the graceful degradation story: batches the device engine
   cannot take fall back to the numpy steppers with identical results.

    PYTHONPATH=src python examples/jax_sweep.py
"""
import time

from repro.core.engine import jax_available
from repro.sim import MonteCarloSuite, SampleSpace, TraceSuite, run_sweep


def jax_parity():
    space = SampleSpace(
        codes=((6, 3), (7, 4)), cluster_sizes=(10,), chunk_mb=(8.0,),
        regimes=("hot2s",), failure_patterns=("single", "double"),
    )
    suite = MonteCarloSuite("jaxdemo", 16, space, base_seed=3)
    serial = run_sweep(suite, executor="serial")
    jaxed = run_sweep(suite, executor="jax")
    worst = max(
        abs(cs.results[s].total_time - cj.results[s].total_time)
        / cs.results[s].total_time
        for cs, cj in zip(serial.cases, jaxed.cases) for s in cs.results
    )
    print(f"16-case sweep, serial vs executor='jax': max relative "
          f"difference = {worst:.2e}")
    print(jaxed.summary_table())
    return worst


def jax_throughput():
    """Execution-bound suite (star fan-in, large chunks, frozen traces):
    where event stepping, not planning, is the bottleneck."""
    space = SampleSpace(
        codes=((14, 10),), cluster_sizes=(14,), chunk_mb=(512.0,),
        regimes=("hot2s",), failure_patterns=("single",),
    )
    live = MonteCarloSuite("stress", 24, space,
                           schemes=("traditional", "ppr"), base_seed=17)
    frozen = TraceSuite.freeze(live, num_epochs=256)
    timings = {}
    for executor in ("vectorized", "jax"):
        run_sweep(frozen, executor=executor)       # warm (compile for jax)
        t0 = time.perf_counter()
        run_sweep(frozen, executor=executor)
        timings[executor] = time.perf_counter() - t0
    print(f"\nexecution-bound 24-case suite (warm): "
          f"numpy vectorized {timings['vectorized'] * 1e3:.0f}ms, "
          f"jax {timings['jax'] * 1e3:.0f}ms on "
          f"{'a CPU device' if jax_available() else 'numpy fallback'}")


def main():
    if not jax_available():
        print("jax is not installed: executor='jax' will warn and fall "
              "back to the numpy vectorized engine (results identical).")
    worst = jax_parity()
    assert worst < 1e-6, "jax executor must match the reference engine"
    jax_throughput()


if __name__ == "__main__":
    main()
