"""Paper Table II: multi-node scheduling steps for RS(7,4), failed {n1,n2}.

Expected: m-PPR 6 timestamps, random 4 (seed-dependent, 3..8), MSRepair 3.
"""
from benchmarks.common import Row
from repro.core.msrepair import plan_mppr, plan_msrepair, plan_random
from repro.core.plan import Job, validate_plan


def run() -> list[Row]:
    jobs = [
        Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3, 4, 5)),
        Job(job_id=1, failed_node=1, requestor=1, helpers=(3, 4, 5, 6)),
    ]
    import time
    rows = []
    for name, planner in (
        ("table2/m-ppr", lambda: plan_mppr(jobs)),
        ("table2/random", lambda: plan_random(jobs, seed=0)),
        ("table2/msrepair", lambda: plan_msrepair(jobs)),
    ):
        t0 = time.perf_counter()
        plan = planner()
        us = (time.perf_counter() - t0) * 1e6
        validate_plan(plan)
        rows.append(Row(name, us, f"timestamps={plan.num_rounds}"))
    ms = plan_msrepair(jobs).num_rounds
    mp = plan_mppr(jobs).num_rounds
    rows.append(Row("table2/msrepair_vs_mppr", 0.0,
                    f"reduction={100 * (1 - ms / mp):.0f}% (paper: 50%)"))
    return rows
