"""Paper Table II: multi-node scheduling steps for RS(7,4), failed {n1,n2}.

Expected: m-PPR 6 timestamps, random 4 (seed-dependent, 3..8), MSRepair 3.

Two parts: (1) the paper's exact RS(7,4) helper assignment, planner-only;
(2) a `MonteCarloSuite` of 60 sampled two-failure RS(7,4) scenarios under
hot churn, executed by a single `run_sweep` invocation — the statistical
version of the table (timestamp counts + simulated repair times per
scheme), which the fixed example alone cannot show.
"""
import time

from benchmarks.common import BENCH_EXECUTOR, Row
from repro.core.msrepair import plan_mppr, plan_msrepair, plan_random
from repro.core.plan import Job, validate_plan
from repro.sim.suite import MonteCarloSuite, SampleSpace
from repro.sim.sweep import run_sweep

SCHEMES = ("mppr", "random", "msrepair")
SWEEP_CASES = 60      # >= 50 sampled scenarios per scheme


def table2_suite(num_cases=SWEEP_CASES) -> MonteCarloSuite:
    space = SampleSpace(
        codes=((7, 4),),
        cluster_sizes=(14,),
        chunk_mb=(32.0,),
        regimes=("hot2s",),
        failure_patterns=("double",),
    )
    return MonteCarloSuite("table2", num_cases, space, schemes=SCHEMES,
                           base_seed=0)


def run() -> list[Row]:
    # -- the paper's exact example -----------------------------------------
    jobs = [
        Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3, 4, 5)),
        Job(job_id=1, failed_node=1, requestor=1, helpers=(3, 4, 5, 6)),
    ]
    rows = []
    for name, planner in (
        ("table2/m-ppr", lambda: plan_mppr(jobs)),
        ("table2/random", lambda: plan_random(jobs, seed=0)),
        ("table2/msrepair", lambda: plan_msrepair(jobs)),
    ):
        t0 = time.perf_counter()
        plan = planner()
        us = (time.perf_counter() - t0) * 1e6
        validate_plan(plan)
        rows.append(Row(name, us, f"timestamps={plan.num_rounds}"))
    ms = plan_msrepair(jobs).num_rounds
    mp = plan_mppr(jobs).num_rounds
    rows.append(Row("table2/msrepair_vs_mppr", 0.0,
                    f"reduction={100 * (1 - ms / mp):.0f}% (paper: 50%)"))

    # -- Monte-Carlo version: 60 sampled two-failure scenarios -------------
    sweep = run_sweep(table2_suite(), executor=BENCH_EXECUTOR)
    for scheme in SCHEMES:
        st = sweep.stats(scheme)
        rows.append(Row(
            f"table2/sweep/{scheme}",
            st.mean_planning * 1e6,
            f"n={st.count} timestamps_mean={st.mean_rounds:.2f} "
            f"time_mean={st.mean:.2f}s p50={st.p50:.2f}s p90={st.p90:.2f}s",
        ))
    rows.append(Row(
        "table2/sweep/summary", 0.0,
        f"ms_vs_mppr reduction=-{sweep.reduction_pct('mppr', 'msrepair'):.1f}% "
        f"speedup p50={sweep.speedup_percentile('mppr', 'msrepair', 50):.2f}x "
        f"p90={sweep.speedup_percentile('mppr', 'msrepair', 90):.2f}x "
        f"(paper: 50% fewer timestamps)",
    ))
    return rows
