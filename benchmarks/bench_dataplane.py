"""Byte data-plane throughput benchmark -> `BENCH_dataplane.json`.

Measures what the repair system ultimately does: *move and recombine
bytes*. A batch of stripes is placed over the Mininet-sized cluster with
`repro.ec.stripe.place_stripes` (rotated RAID-5-style placement), each
stripe gets a real simulator-produced repair plan (ppr/bmf alternating,
relabeled through its placement), and the same byte workload runs twice:

* **serial** — `repro.core.executor.execute_plan` per stripe, the
  per-transfer dict walk with one kernel/ref call per chunk (the
  pre-batched data plane, kept as the oracle);
* **batched** — `repro.core.engine.dataplane.execute_plans_batch`, the
  whole batch lowered to `(B, slots, nbytes)` buffer tensors and executed
  as gather / GF(256)-premultiply / segment-XOR array steps.

Two paths each: the **ref** (non-interpret) path — numpy oracles batched
vs per-chunk jnp calls, the honest CPU-throughput number CI gates at
>= 3x batched-vs-serial on a >= 64-stripe batch — and the **kernel
(interpret)** path on a small slice, which exercises the exact Pallas
kernel bodies (`gf256_scale_planes` / `xor_reduce_groups_words` grids vs
one `pallas_call` per chunk); interpret mode is a correctness path, not
a performance proxy, so its split is informational.

`--small` (or REPRO_BENCH_DATAPLANE_SMALL=1) shrinks chunk size for CI
but keeps the 64-stripe batch the acceptance gate is defined over.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

SMALL = ("--small" in sys.argv
         or os.environ.get("REPRO_BENCH_DATAPLANE_SMALL") == "1")
BATCH = 64 if SMALL else 128
NBYTES = 4096 if SMALL else 16384
KERNEL_BATCH = 4 if SMALL else 8
KERNEL_NBYTES = 256
REPEATS = int(os.environ.get("REPRO_BENCH_DATAPLANE_REPEATS", "3"))
OUT_PATH = "BENCH_dataplane.json"
CLUSTER = 14
CODE_NK = (6, 3)
SCHEMES = ("ppr", "bmf")


def _build_batch(batch: int, nbytes: int):
    """`batch` placed stripes, each with its own executed repair plan."""
    from benchmarks.common import mininet_scenario
    from repro.core.engine.arrays import compile_plan, relabel_plan_nodes
    from repro.core.simulator import run_scheme
    from repro.ec.rs import RSCode
    from repro.ec.stripe import place_stripes, split_blob

    n, k = CODE_NK
    code = RSCode(n, k)
    rng = np.random.default_rng(2026)
    blob = rng.integers(0, 256, size=batch * k * nbytes, dtype=np.uint8)
    datas = split_blob(blob, k, nbytes)
    stripes = place_stripes(batch, code, CLUSTER)
    pas, plans, cws, bmaps = [], [], [], []
    for b in range(batch):
        scheme = SCHEMES[b % len(SCHEMES)]
        sc = mininet_scenario(n, k, (b % n,), chunk_mb=4.0, seed=b)
        plan = run_scheme(sc, scheme).plan
        pa = relabel_plan_nodes(compile_plan(plan),
                                stripes[b].perm(CLUSTER))
        pas.append(pa)
        cws.append(code.encode(datas[b]))
        bmaps.append(stripes[b].block_map(CLUSTER))
    return code, pas, cws, bmaps


def _time_serial(code, pas, cws, bmaps, *, use_kernel, interpret=None):
    from repro.core.engine.arrays import decompile
    from repro.core.executor import execute_plan

    plans = [decompile(pa) for pa in pas]
    best, moved = float("inf"), 0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        total = 0
        for plan, cw, bmap in zip(plans, cws, bmaps):
            ex = execute_plan(plan, code, cw, use_kernel=use_kernel,
                              block_of=bmap)
            assert ex.verified, "serial data plane failed verification"
            total += ex.bytes_moved
        best = min(best, time.perf_counter() - t0)
        moved = total
    return best, moved


def _time_batched(code, pas, cws, bmaps, *, use_kernel, interpret=None):
    from repro.core.engine.dataplane import execute_plans_batch

    best, moved = float("inf"), 0
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        res = execute_plans_batch(pas, code, cws, block_of=bmaps,
                                  use_kernel=use_kernel, interpret=interpret)
        assert res.all_verified, "batched data plane failed verification"
        best = min(best, time.perf_counter() - t0)
        moved = int(res.bytes_moved.sum())
    return best, moved


def _entry(seconds: float, moved: int, serial_s: float | None = None) -> dict:
    out = {
        "seconds": round(seconds, 4),
        "mb_per_sec": round(moved / seconds / 1e6, 2),
        "bytes_moved": moved,
    }
    if serial_s is not None:
        out["speedup_vs_serial"] = round(serial_s / seconds, 2)
    return out


def run():
    from benchmarks.common import Row

    code, pas, cws, bmaps = _build_batch(BATCH, NBYTES)
    report: dict = {
        "batch": BATCH, "nbytes": NBYTES, "cluster": CLUSTER,
        "code": CODE_NK, "schemes": list(SCHEMES), "dataplane": {},
    }
    dp = report["dataplane"]

    ser_s, moved = _time_serial(code, pas, cws, bmaps, use_kernel=False)
    dp["serial_ref"] = _entry(ser_s, moved)
    bat_s, moved_b = _time_batched(code, pas, cws, bmaps, use_kernel=False)
    assert moved_b == moved, "serial/batched bytes_moved accounting diverged"
    dp["batched_ref"] = _entry(bat_s, moved_b, ser_s)

    kcode, kpas, kcws, kbmaps = _build_batch(KERNEL_BATCH, KERNEL_NBYTES)
    kser_s, kmoved = _time_serial(kcode, kpas, kcws, kbmaps,
                                  use_kernel=True)
    dp["serial_kernel_interpret"] = _entry(kser_s, kmoved)
    kbat_s, _ = _time_batched(kcode, kpas, kcws, kbmaps,
                              use_kernel=True, interpret=None)
    dp["batched_kernel_interpret"] = _entry(kbat_s, kmoved, kser_s)

    dp["verified"] = True   # every timed run asserted byte-exactness
    report["batched_ref_ge_3x"] = \
        dp["batched_ref"]["speedup_vs_serial"] >= 3.0
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        Row(f"dataplane/{name}", entry["seconds"] * 1e6 / BATCH,
            f"mb_per_sec={entry['mb_per_sec']}"
            + (f" speedup_vs_serial={entry['speedup_vs_serial']}x"
               if "speedup_vs_serial" in entry else ""))
        for name, entry in dp.items() if isinstance(entry, dict)
    ]
    rows.append(Row("dataplane/json", 0.0, f"wrote {OUT_PATH}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row.csv())
