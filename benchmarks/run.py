"""Benchmark driver — one module per paper table/figure.

Prints `name,us_per_call,derived` CSV. us_per_call is the mean planning /
algorithm wall-time per repair (the paper's Fig. 8 overhead axis); derived
carries each figure's headline metric with the paper's claimed number for
comparison. Roofline terms for the LM cells come from launch/dryrun.py
(see EXPERIMENTS.md), not from this driver.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_ablation, bench_aliyun, bench_dataplane,
                            bench_fig8, bench_fig9, bench_fig10, bench_fig11,
                            bench_kernels, bench_sweep, bench_table2)
    modules = [
        ("table2", bench_table2),
        ("fig8", bench_fig8),
        ("fig9", bench_fig9),
        ("fig10", bench_fig10),
        ("fig11", bench_fig11),
        ("aliyun", bench_aliyun),
        ("kernels", bench_kernels),
        ("ablation", bench_ablation),
        ("sweep", bench_sweep),
        ("dataplane", bench_dataplane),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and only != name:
            continue
        t0 = time.time()
        for row in mod.run():
            print(row.csv())
        print(f"# {name} finished in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
