"""EC data-plane kernel throughput (CPU interpret mode) + the projected
TPU roofline for the bit-plane GF(256) kernel.

The kernel is bandwidth-bound by design: per output byte it moves
(k+1)/k input+output bytes and performs 8*k bit-ops on 1/8-width planes
-> arithmetic intensity ~ 2*k VPU-ops/byte. On v5e (819 GB/s HBM) the
roofline is HBM: projected encode rate ~ HBM_bw / (1 + (n-k)/k) per chip.
"""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.ec.rs import RSCode
from repro.kernels import ops, ref

HBM_BW = 819e9


def _bench(fn, *args, reps=3):
    fn(*args)                      # warm up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jnp.asarray(out).block_until_ready()
    return (time.perf_counter() - t0) / reps


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for (n, k) in [(6, 3), (7, 4)]:
        code = RSCode(n, k)
        nbytes = 1 << 18
        data = jnp.asarray(
            rng.integers(0, 256, size=(k, nbytes), dtype=np.uint8))
        coeff = code.parity_coeffs()

        t_kernel = _bench(lambda: ops.rs_encode(coeff, data))
        t_ref = _bench(lambda: ref.gf256_matmul_bytes_ref(coeff, data))
        mbps = k * nbytes / t_kernel / 2**20
        # projected on-TPU rate (bandwidth-bound bit-plane kernel)
        proj = HBM_BW / (1 + (n - k) / k) / 2**30
        rows.append(Row(
            f"kernels/rs{n}{k}_encode_256KBx{k}",
            t_kernel * 1e6,
            f"interpret={mbps:.0f}MB/s ref_ratio={t_ref / t_kernel:.2f}x "
            f"tpu_roofline~{proj:.0f}GiB/s/chip (HBM-bound)",
        ))

    x = jnp.asarray(rng.integers(0, 256, size=(4, 1 << 19), dtype=np.uint8))
    t_x = _bench(lambda: ops.xor_reduce(x))
    rows.append(Row(
        "kernels/xor_reduce_512KBx4",
        t_x * 1e6,
        f"interpret={2 / t_x:.0f}MB/s tpu_roofline~{819 / (1 + 1 / 4):.0f}GB/s",
    ))
    return rows
