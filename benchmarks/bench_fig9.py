"""Paper Fig. 9: single-node recovery time vs chunk size —
traditional vs PPR vs BMFRepair for RS(4,2), RS(6,3), RS(7,4).

Paper claims: BMF cuts ~23-25% vs PPR (up to 42.1%), up to 64.9% vs
traditional; gains grow with n-k (more idle forwarders).
"""
from benchmarks.common import Row, mininet_scenario, reduction, run_trials

SCHEMES = ("traditional", "ppr", "bmf")


def run() -> list[Row]:
    rows = []
    for (n, k) in [(4, 2), (6, 3), (7, 4)]:
        for chunk in (8, 16, 32):
            res = run_trials(
                lambda seed: mininet_scenario(n, k, (0,), chunk_mb=chunk,
                                              seed=seed),
                SCHEMES)
            t_t, _, _ = res["traditional"]
            t_p, _, plan_p = res["ppr"]
            t_b, _, plan_b = res["bmf"]
            rows.append(Row(
                f"fig9/rs{n}{k}/chunk{chunk}MB",
                plan_b * 1e6,
                f"trad={t_t:.2f}s ppr={t_p:.2f}s bmf={t_b:.2f}s "
                f"bmf_vs_ppr=-{reduction(t_p, t_b):.1f}% "
                f"bmf_vs_trad=-{reduction(t_t, t_b):.1f}%",
            ))
    return rows
