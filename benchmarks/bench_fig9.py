"""Paper Fig. 9: single-node recovery time vs chunk size —
traditional vs PPR vs BMFRepair for RS(4,2), RS(6,3), RS(7,4).

Paper claims: BMF cuts ~23-25% vs PPR (up to 42.1%), up to 64.9% vs
traditional; gains grow with n-k (more idle forwarders).

Declarative: the whole figure is one `GridSuite` (3 codes x 3 chunk sizes
x 20 trials = 180 scenarios per scheme) executed by a single `run_sweep`
invocation; rows are per-cell summaries of the sweep result.
"""
from benchmarks.common import (BENCH_EXECUTOR, TRIALS, Row, mininet_scenario,
                               reduction)
from repro.sim.suite import GridSuite
from repro.sim.sweep import run_sweep

SCHEMES = ("traditional", "ppr", "bmf")
CODES = [(4, 2), (6, 3), (7, 4)]
CHUNKS_MB = [8, 16, 32]


def fig9_suite(trials=TRIALS) -> GridSuite:
    return GridSuite(
        "fig9",
        axes={"code": CODES, "chunk_mb": CHUNKS_MB},
        build=lambda p, seed: mininet_scenario(
            *p["code"], (0,), chunk_mb=p["chunk_mb"], seed=seed),
        trials=trials,
        schemes=SCHEMES,
    )


def run() -> list[Row]:
    sweep = run_sweep(fig9_suite(), executor=BENCH_EXECUTOR)
    groups = sweep.group_by("code", "chunk_mb")
    rows = []
    for (n, k) in CODES:
        for chunk in CHUNKS_MB:
            cell = groups[((n, k), chunk)]
            t_t = cell.stats("traditional").mean
            t_p = cell.stats("ppr").mean
            bmf = cell.stats("bmf")
            rows.append(Row(
                f"fig9/rs{n}{k}/chunk{chunk}MB",
                bmf.mean_planning * 1e6,
                f"trad={t_t:.2f}s ppr={t_p:.2f}s bmf={bmf.mean:.2f}s "
                f"bmf_vs_ppr=-{reduction(t_p, bmf.mean):.1f}% "
                f"bmf_vs_trad=-{reduction(t_t, bmf.mean):.1f}%",
            ))
    rows.append(Row(
        "fig9/summary", 0.0,
        f"n={len(sweep)} scenarios/scheme; bmf_vs_ppr reduction="
        f"-{sweep.reduction_pct('ppr', 'bmf'):.1f}% "
        f"speedup p10={sweep.speedup_percentile('ppr', 'bmf', 10):.2f}x "
        f"p50={sweep.speedup_percentile('ppr', 'bmf', 50):.2f}x "
        f"p90={sweep.speedup_percentile('ppr', 'bmf', 90):.2f}x "
        f"(paper: ~23-25%, max 42.1%)",
    ))
    return rows
