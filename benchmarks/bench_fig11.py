"""Paper Fig. 11: BMFRepair vs PPT under low (5 s) / high (2 s) bandwidth
churn, RS(4,2), chunks 8/16/32 MB.

Paper claims: comparable at 8/16 MB low-churn; BMF ~25% lower at 32 MB
hot; PPT fluctuates much more (plan-once + multi-link sensitivity).
"""
import numpy as np

from benchmarks.common import Row, mininet_scenario, reduction, run_trials

SCHEMES = ("bmf", "ppt")


def run() -> list[Row]:
    rows = []
    for label, interval in (("cold5s", 5.0), ("hot2s", 2.0)):
        for chunk in (8, 16, 32):
            res = run_trials(
                lambda seed: mininet_scenario(4, 2, (0,), chunk_mb=chunk,
                                              seed=seed, interval=interval),
                SCHEMES)
            t_b, sd_b, plan_b = res["bmf"]
            t_p, sd_p, _ = res["ppt"]
            rows.append(Row(
                f"fig11/{label}/chunk{chunk}MB",
                plan_b * 1e6,
                f"bmf={t_b:.2f}s(std{sd_b:.2f}) ppt={t_p:.2f}s(std{sd_p:.2f}) "
                f"bmf_vs_ppt=-{reduction(t_p, t_b):.1f}% "
                f"ppt_fluct_ratio={sd_p / max(sd_b, 1e-9):.1f}x",
            ))
    return rows
