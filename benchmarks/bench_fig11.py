"""Paper Fig. 11: BMFRepair vs PPT under low (5 s) / high (2 s) bandwidth
churn, RS(4,2), chunks 8/16/32 MB.

Paper claims: comparable at 8/16 MB low-churn; BMF ~25% lower at 32 MB
hot; PPT fluctuates much more (plan-once + multi-link sensitivity).

Declarative: one `GridSuite` over churn regime x chunk size x 20 trials,
executed by a single `run_sweep` invocation; PPT's fluctuation shows up
directly in the per-cell std ratio.
"""
from benchmarks.common import (BENCH_EXECUTOR, TRIALS, Row, mininet_scenario,
                               reduction)
from repro.sim.suite import GridSuite
from repro.sim.sweep import run_sweep

SCHEMES = ("bmf", "ppt")
REGIMES = [("cold5s", 5.0), ("hot2s", 2.0)]
CHUNKS_MB = [8, 16, 32]


def fig11_suite(trials=TRIALS) -> GridSuite:
    return GridSuite(
        "fig11",
        axes={"regime": REGIMES, "chunk_mb": CHUNKS_MB},
        build=lambda p, seed: mininet_scenario(
            4, 2, (0,), chunk_mb=p["chunk_mb"], seed=seed,
            interval=p["regime"][1]),
        trials=trials,
        schemes=SCHEMES,
    )


def run() -> list[Row]:
    sweep = run_sweep(fig11_suite(), executor=BENCH_EXECUTOR)
    groups = sweep.group_by("regime", "chunk_mb")
    rows = []
    for regime in REGIMES:
        for chunk in CHUNKS_MB:
            cell = groups[(regime, chunk)]
            bmf = cell.stats("bmf")
            ppt = cell.stats("ppt")
            rows.append(Row(
                f"fig11/{regime[0]}/chunk{chunk}MB",
                bmf.mean_planning * 1e6,
                f"bmf={bmf.mean:.2f}s(std{bmf.std:.2f}) "
                f"ppt={ppt.mean:.2f}s(std{ppt.std:.2f}) "
                f"bmf_vs_ppt=-{reduction(ppt.mean, bmf.mean):.1f}% "
                f"ppt_fluct_ratio={ppt.std / max(bmf.std, 1e-9):.1f}x",
            ))
    return rows
