"""Paper Fig. 8: fraction of repair time spent on coding + algorithm
(everything except network transmission). Paper: ~3% — the pruned DFS is
cheap, so BMFRepair scales to large networks.
"""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, mininet_scenario, run_trials
from repro.core import executor
from repro.core.simulator import RepairSimulator
from repro.ec.rs import RSCode
from repro.kernels import ops


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)
    for (n, k) in [(4, 2), (6, 3), (7, 4)]:
        for chunk in (8, 32):
            sc = mininet_scenario(n, k, (0,), chunk_mb=chunk, seed=3)
            sim = RepairSimulator(sc)
            r = sim.run("bmf")
            # coding cost: premultiply k chunks + k-1 XOR merges, measured
            # on the real kernels (MB-sized buffers, interpret mode)
            code = RSCode(n, k)
            data = rng.integers(0, 256, size=(k, chunk << 20),
                                dtype=np.uint8)
            coeff = code.repair_coeffs((0,), tuple(range(1, k + 1)))
            # compiled byte-domain path (the CPU-executable data plane;
            # the Pallas kernel is the TPU target, interpret mode is a
            # correctness harness, not a performance proxy)
            fn = lambda: np.asarray(
                ops.rs_reconstruct.__wrapped__(coeff, jnp.asarray(data))
                if hasattr(ops.rs_reconstruct, "__wrapped__") else
                ops.gf256_matmul(coeff, jnp.asarray(data), use_kernel=False))
            fn()                                      # compile
            t0 = time.perf_counter()
            fn()
            coding_s = time.perf_counter() - t0
            plan_frac = 100 * r.planning_time / (r.total_time + r.planning_time)
            overhead = r.planning_time + coding_s
            frac = 100 * overhead / (r.total_time + overhead)
            rows.append(Row(
                f"fig8/rs{n}{k}/chunk{chunk}MB",
                r.planning_time * 1e6,
                f"plan_frac={plan_frac:.2f}% code={coding_s:.2f}s "
                f"transfer={r.total_time:.2f}s total_overhead={frac:.1f}% "
                f"(paper ~3%; coding on CPU-jnp — ISA-L/TPU-grade GF "
                f"kernels push this to the paper's level)",
            ))
    return rows
