"""Paper Fig. 8: fraction of repair time spent on coding + algorithm
(everything except network transmission). Paper: ~3% — the pruned DFS is
cheap, so BMFRepair scales to large networks.

The simulation half is a declarative `GridSuite` (code x chunk, one seeded
trial each, matching the legacy seed) run by one sweep invocation; the
coding half times the real GF(256) kernels per cell.
"""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_EXECUTOR, Row, mininet_scenario
from repro.ec.rs import RSCode
from repro.kernels import ops
from repro.sim.suite import GridSuite
from repro.sim.sweep import run_sweep

CODES = [(4, 2), (6, 3), (7, 4)]
CHUNKS_MB = [8, 32]


def fig8_suite() -> GridSuite:
    return GridSuite(
        "fig8",
        axes={"code": CODES, "chunk_mb": CHUNKS_MB},
        build=lambda p, seed: mininet_scenario(
            *p["code"], (0,), chunk_mb=p["chunk_mb"], seed=seed),
        trials=1,
        schemes=("bmf",),
        base_seed=3,
    )


def run() -> list[Row]:
    rows = []
    rng = np.random.default_rng(0)
    sweep = run_sweep(fig8_suite(), executor=BENCH_EXECUTOR)
    groups = sweep.group_by("code", "chunk_mb")
    for (n, k) in CODES:
        for chunk in CHUNKS_MB:
            r = groups[((n, k), chunk)].cases[0].results["bmf"]
            # coding cost: premultiply k chunks + k-1 XOR merges, measured
            # on the real kernels (MB-sized buffers, interpret mode)
            code = RSCode(n, k)
            data = rng.integers(0, 256, size=(k, chunk << 20),
                                dtype=np.uint8)
            coeff = code.repair_coeffs((0,), tuple(range(1, k + 1)))
            # compiled byte-domain path (the CPU-executable data plane;
            # the Pallas kernel is the TPU target, interpret mode is a
            # correctness harness, not a performance proxy)
            fn = lambda: np.asarray(
                ops.rs_reconstruct.__wrapped__(coeff, jnp.asarray(data))
                if hasattr(ops.rs_reconstruct, "__wrapped__") else
                ops.gf256_matmul(coeff, jnp.asarray(data), use_kernel=False))
            fn()                                      # compile
            t0 = time.perf_counter()
            fn()
            coding_s = time.perf_counter() - t0
            plan_frac = 100 * r.planning_time / (r.total_time + r.planning_time)
            overhead = r.planning_time + coding_s
            frac = 100 * overhead / (r.total_time + overhead)
            rows.append(Row(
                f"fig8/rs{n}{k}/chunk{chunk}MB",
                r.planning_time * 1e6,
                f"plan_frac={plan_frac:.2f}% code={coding_s:.2f}s "
                f"transfer={r.total_time:.2f}s total_overhead={frac:.1f}% "
                f"(paper ~3%; coding on CPU-jnp — ISA-L/TPU-grade GF "
                f"kernels push this to the paper's level)",
            ))
    return rows
