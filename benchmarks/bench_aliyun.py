"""Paper Figs. 12/13: geo-distributed repair on the measured Aliyun ECS
bandwidth matrix (Table III), 128 MB blocks.

Fig. 12 (single failure, RS(4,2)/(4,3)/(6,3)/(6,4)): PPT longest at
RS(4,2)/(6,3); BMF ~15.9% (avg) / 23.4% (max) under PPR, ~19.3% under PPT.
Fig. 13 (two failures): MSRepair ~20.6% under m-PPR on average.
"""
from benchmarks.common import Row, aliyun_scenario, reduction, run_trials


def run() -> list[Row]:
    rows = []
    bmf_vs_ppr, bmf_vs_ppt = [], []
    for (n, k) in [(4, 2), (4, 3), (6, 3), (6, 4)]:
        res = run_trials(
            lambda seed: aliyun_scenario(n, k, (seed % n,), chunk_mb=128,
                                         seed=seed),
            ("ppr", "ppt", "bmf"))
        t_p, _, _ = res["ppr"]
        t_t, sd_t, _ = res["ppt"]
        t_b, _, plan_b = res["bmf"]
        bmf_vs_ppr.append(reduction(t_p, t_b))
        bmf_vs_ppt.append(reduction(t_t, t_b))
        rows.append(Row(
            f"fig12/rs{n}{k}/128MB",
            plan_b * 1e6,
            f"ppr={t_p:.1f}s ppt={t_t:.1f}s bmf={t_b:.1f}s "
            f"bmf_vs_ppr={-reduction(t_p, t_b):+.1f}% "
            f"bmf_vs_ppt={-reduction(t_t, t_b):+.1f}%",
        ))
    rows.append(Row(
        "fig12/summary", 0.0,
        f"avg bmf_vs_ppr={-sum(bmf_vs_ppr)/len(bmf_vs_ppr):+.1f}% "
        f"(paper avg -15.9%, max -23.4%); "
        f"avg bmf_vs_ppt={-sum(bmf_vs_ppt)/len(bmf_vs_ppt):+.1f}% "
        f"(paper avg -19.3%, max -22.4%)"))

    gains = []
    for (n, k) in [(6, 3), (6, 4)]:
        res = run_trials(
            lambda seed: aliyun_scenario(n, k, (seed % n, (seed + 1) % n),
                                         chunk_mb=128, seed=seed),
            ("mppr", "msrepair"))
        t_m, _, _ = res["mppr"]
        t_s, plan_s = res["msrepair"][0], res["msrepair"][2]
        gains.append(reduction(t_m, t_s))
        rows.append(Row(
            f"fig13/rs{n}{k}/128MB",
            plan_s * 1e6,
            f"mppr={t_m:.1f}s msrepair={t_s:.1f}s "
            f"ms_vs_mppr=-{reduction(t_m, t_s):.1f}%",
        ))
    rows.append(Row(
        "fig13/summary", 0.0,
        f"avg ms_vs_mppr=-{sum(gains)/len(gains):.1f}% (paper avg 20.6%)"))
    return rows
