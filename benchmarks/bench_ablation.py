"""Beyond-paper ablations of BMFRepair's design choices.

1. Per-round replanning vs plan-once (bmf vs bmf_static): isolates the
   paper's central "monitor in real time, locally optimal per timestamp"
   mechanism from the relay mechanism itself.
2. optimize_all extension: after the bottleneck link stops improving, also
   reroute the non-bottleneck links (the paper stops at the bottleneck).
3. Idle-pool size: the paper argues "the more idle nodes, the more paths
   for optimal forwarding" — sweep the cluster size at fixed RS(6,3).
"""
import numpy as np

from benchmarks.common import Row, mininet_scenario, reduction
from repro.core.simulator import RepairSimulator


def _times(make_sc, schemes, trials=20, **sim_kw):
    out = {s: [] for s in schemes}
    for seed in range(trials):
        sim = RepairSimulator(make_sc(seed), **sim_kw)
        for s in schemes:
            out[s].append(sim.run(s).total_time)
    return {s: float(np.mean(v)) for s, v in out.items()}


def run() -> list[Row]:
    rows = []
    # 1. replanning ablation (hot churn, where it should matter most)
    res = _times(lambda seed: mininet_scenario(6, 3, (0,), chunk_mb=32,
                                               seed=seed, interval=2.0),
                 ("ppr", "bmf_static", "bmf"))
    rows.append(Row(
        "ablation/replanning", 0.0,
        f"ppr={res['ppr']:.2f}s plan_once_bmf={res['bmf_static']:.2f}s "
        f"per_round_bmf={res['bmf']:.2f}s — replanning adds "
        f"{reduction(res['bmf_static'], res['bmf']):.1f}% on top of relays "
        f"({reduction(res['ppr'], res['bmf_static']):.1f}%)"))

    # 2. optimize_all (beyond-paper: reroute non-bottleneck links too)
    t_base = _times(lambda seed: mininet_scenario(7, 4, (0,), chunk_mb=32,
                                                  seed=seed), ("bmf",))
    t_all = _times(lambda seed: mininet_scenario(7, 4, (0,), chunk_mb=32,
                                                 seed=seed), ("bmf",),
                   bmf_optimize_all=True)
    rows.append(Row(
        "ablation/optimize_all", 0.0,
        f"bottleneck_only={t_base['bmf']:.2f}s all_links={t_all['bmf']:.2f}s "
        f"delta={reduction(t_base['bmf'], t_all['bmf']):+.1f}% "
        f"(beyond-paper extension)"))

    # 2b. where the estimated savings come from: BMFStats attributes the
    # paper's bottleneck loop vs the optimize_all extension separately
    from repro.core import bmf
    from repro.core.simulator import _idle_pool, plan_for_scheme

    saved_bn = saved_ex = 0.0
    for seed in range(10):
        sc = mininet_scenario(7, 4, (0,), chunk_mb=32, seed=seed)
        jobs = sc.make_jobs()
        plan = plan_for_scheme("bmf", jobs)
        bw0 = sc.bw.matrix_at(0.0)
        for rnd in plan.rounds:
            idle = [x for x in _idle_pool(sc, jobs)
                    if x not in rnd.nodes_in_use()]
            _, st = bmf.optimize_round(rnd, bw0, idle, sc.chunk_mb,
                                       optimize_all=True)
            saved_bn += st.time_saved_bottleneck
            saved_ex += st.time_saved_extra
    rows.append(Row(
        "ablation/optimize_all_attribution", 0.0,
        f"est_saved bottleneck_loop={saved_bn:.1f}s "
        f"optimize_all_extra={saved_ex:.1f}s over 10 t=0 plans "
        f"(extra share={100 * saved_ex / max(saved_bn + saved_ex, 1e-9):.0f}%)"))

    # 3. idle-pool sweep (paper: larger n-k-1 / idle pool -> better)
    for cluster in (6, 8, 10, 14):
        res = _times(lambda seed: mininet_scenario(
            6, 3, (0,), chunk_mb=32, seed=seed, cluster=cluster),
            ("ppr", "bmf"))
        rows.append(Row(
            f"ablation/idle_pool/cluster{cluster}", 0.0,
            f"ppr={res['ppr']:.2f}s bmf={res['bmf']:.2f}s "
            f"gain=-{reduction(res['ppr'], res['bmf']):.1f}% "
            f"(idle={cluster - 4})"))
    return rows
