"""Shared benchmark harness for the paper-reproduction experiments.

Every bench_* module exposes `run() -> list[Row]`; benchmarks.run prints
them as `name,us_per_call,derived` CSV (us_per_call = mean planning/
algorithm wall-time per repair; derived = the figure's headline metric).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario
from repro.ec.rs import RSCode

# The paper's Mininet testbed: 14 hosts, heterogeneous links, hot churn 2 s
MININET_HOSTS = 14
BW_LOW, BW_HIGH = 3.0, 30.0
TRIALS = 20                      # "We run each group of experiments over 20 times"


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def mininet_scenario(n, k, failed, *, chunk_mb, seed, interval=2.0,
                     cluster=MININET_HOSTS, mode="markov"):
    base = topology.heterogeneous_matrix(cluster, low=BW_LOW, high=BW_HIGH,
                                         seed=1000 + seed)
    bwp = BandwidthProcess(base=base, change_interval=interval, seed=seed,
                           mode=mode)
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk_mb)


def aliyun_scenario(n, k, failed, *, chunk_mb, seed, interval=2.0):
    """Geo-distributed: the measured Table III matrix + heavy markov churn.

    The measured matrix nearly satisfies the triangle inequality, so
    static relaying cannot win; the paper's Aliyun gains come from VM-load
    drift ("bandwidth obtained ... deviated from the theoretical value, ...
    changes more drastically") — modeled as a fast, high-variance markov
    process on top of Table III. Helpers rotate with the failed node so
    different codes exercise different link subsets.
    """
    _, base = topology.aliyun_matrix()
    bwp = BandwidthProcess(base=base, change_interval=interval, seed=seed,
                           mode="markov", sigma=1.0, rho=0.9)
    # cloud ingress profile: 2-vCPU ecs.sn2ne.large instances — multi-link
    # TCP collapses harder than on the Mininet testbed (paper's Fig. 12
    # analysis), so fan-in degradation / split skew / duplex are harsher.
    ingress = IngressModel(seed=seed, degrade=0.15, floor=0.3, alpha=0.7,
                           duplex=0.5)
    return Scenario(num_nodes=6, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=ingress, chunk_mb=chunk_mb)


def run_trials(make_scenario, schemes, trials=TRIALS):
    """-> {scheme: (mean_time, std_time, mean_plan_seconds)}"""
    times = {s: [] for s in schemes}
    plans = {s: [] for s in schemes}
    for seed in range(trials):
        sc = make_scenario(seed)
        sim = RepairSimulator(sc, random_seed=seed)
        for s in schemes:
            r = sim.run(s)
            times[s].append(r.total_time)
            plans[s].append(r.planning_time)
    return {
        s: (float(np.mean(times[s])), float(np.std(times[s])),
            float(np.mean(plans[s])))
        for s in schemes
    }


def reduction(base: float, new: float) -> float:
    return 100.0 * (1.0 - new / base)
