"""Shared benchmark harness for the paper-reproduction experiments.

Every bench_* module exposes `run() -> list[Row]`; benchmarks.run prints
them as `name,us_per_call,derived` CSV (us_per_call = mean planning/
algorithm wall-time per repair; derived = the figure's headline metric).

Since the sweep engine landed, each figure is a *declarative suite
definition* (a `GridSuite`/`MonteCarloSuite` in its bench module) executed
by one `repro.sim.sweep.run_sweep` call; this module keeps the scenario
factories, the CSV row type, and a legacy-compatible `run_trials` wrapper.
Set REPRO_SWEEP_EXECUTOR=serial|thread|process|vectorized|jax|auto to
pick the dispatcher (default vectorized: the batched array engine from
`repro.core.engine`, which matches the serial executor case for case;
jax runs the same engine with the jit device steppers from
`repro.core.engine.jax_stepper`, still case-for-case identical).
"""
from __future__ import annotations

import dataclasses
import os

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import Scenario
from repro.ec.rs import RSCode
from repro.sim.suite import GridSuite
from repro.sim.sweep import run_sweep

# The paper's Mininet testbed: 14 hosts, heterogeneous links, hot churn 2 s
MININET_HOSTS = 14
BW_LOW, BW_HIGH = 3.0, 30.0
TRIALS = 20                      # "We run each group of experiments over 20 times"

BENCH_EXECUTOR = os.environ.get("REPRO_SWEEP_EXECUTOR", "vectorized")


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def mininet_scenario(n, k, failed, *, chunk_mb, seed, interval=2.0,
                     cluster=MININET_HOSTS, mode="markov"):
    base = topology.heterogeneous_matrix(cluster, low=BW_LOW, high=BW_HIGH,
                                         seed=1000 + seed)
    bwp = BandwidthProcess(base=base, change_interval=interval, seed=seed,
                           mode=mode)
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk_mb)


def aliyun_scenario(n, k, failed, *, chunk_mb, seed, interval=2.0):
    """Geo-distributed: the measured Table III matrix + heavy markov churn.

    The measured matrix nearly satisfies the triangle inequality, so
    static relaying cannot win; the paper's Aliyun gains come from VM-load
    drift ("bandwidth obtained ... deviated from the theoretical value, ...
    changes more drastically") — modeled as a fast, high-variance markov
    process on top of Table III. Helpers rotate with the failed node so
    different codes exercise different link subsets.
    """
    _, base = topology.aliyun_matrix()
    bwp = BandwidthProcess(base=base, change_interval=interval, seed=seed,
                           mode="markov", sigma=1.0, rho=0.9)
    # cloud ingress profile: 2-vCPU ecs.sn2ne.large instances — multi-link
    # TCP collapses harder than on the Mininet testbed (paper's Fig. 12
    # analysis), so fan-in degradation / split skew / duplex are harsher.
    ingress = IngressModel(seed=seed, degrade=0.15, floor=0.3, alpha=0.7,
                           duplex=0.5)
    return Scenario(num_nodes=6, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=ingress, chunk_mb=chunk_mb)


def trial_suite(name, make_scenario, schemes, trials=TRIALS) -> GridSuite:
    """A suite of `trials` seeded repetitions of one scenario factory
    (seed = trial index, the legacy serial-loop convention)."""
    return GridSuite(
        name, axes={}, build=lambda params, seed: make_scenario(seed),
        trials=trials, schemes=schemes,
    )


def run_trials(make_scenario, schemes, trials=TRIALS):
    """-> {scheme: (mean_time, std_time, mean_plan_seconds)}

    Legacy entry point, now a thin wrapper over the sweep engine: results
    are identical to the old serial loop (same seeds, same scenarios),
    but cases dispatch concurrently.
    """
    sweep = run_sweep(
        trial_suite("trials", make_scenario, schemes, trials),
        executor=BENCH_EXECUTOR,
    )
    return {
        s: (sweep.stats(s).mean, sweep.stats(s).std, sweep.stats(s).mean_planning)
        for s in schemes
    }


def reduction(base: float, new: float) -> float:
    return 100.0 * (1.0 - new / base)
