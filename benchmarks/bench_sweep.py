"""Sweep-executor throughput benchmark -> `BENCH_sweep.json`.

Times `run_sweep` under the serial, process, vectorized and (when jax
is importable) jax executors on fixed 60-case suites (all executors
produce identical results — only wall-clock differs) and writes
cases/sec plus speedups-vs-serial to `BENCH_sweep.json` in the working
directory, so the sweep-throughput trajectory is tracked per PR. The
`jax` rows time the jit device steppers (`repro.core.engine.jax_stepper`)
including compilation on the first repeat; on CPU they clear serial on
the trace-frozen suites (~3.5x on the execution-bound one, still below
the tuned numpy engine) but can land *under* serial on the tiny live
Table II suite, where jit compilation and per-round dispatch dominate a
sub-100ms sweep. The column exists to track the accelerator seam — the
same compiled programs run unchanged on TPU/GPU — not to claim a CPU
win.

Three suites, separating the two bottlenecks a sweep can have:

* ``table2_60`` — the paper's Table II Monte-Carlo suite (RS(7,4) double
  failures, hot churn). *Planner-bound*: most wall-clock is scheduling
  (m-PPR/random/MSRepair) plus bandwidth-epoch rng. Since the
  array-native planner layer landed (batched MSRepair scheduling, batched
  plan lowering/validation, in-stepper BMF replanning), the vectorized
  executor beats serial here too — the json records the planner/exec
  wall-clock split per executor so the remaining ceiling is visible.
* ``table2_60_trace`` — the same 60 scenarios with their bandwidth sample
  paths frozen to replayable traces (`TraceSuite.freeze`), removing the
  shared epoch-rng cost from the comparison. This is the regression-gated
  planner-bound suite (CI asserts its vectorized speedup).
* ``stress_60_trace`` — an *execution-bound* suite (RS(14,10) star +
  binomial-tree repair, 1 GB chunks, hot churn, frozen traces): tens of
  thousands of contention-resolution events and almost no planning. This
  is where executor throughput is actually the bottleneck, and where the
  batched engine's >= 5x-over-serial target is asserted.

Set REPRO_BENCH_SWEEP_CASES to shrink the suites (CI runs the small
variant) — the json records the case count used.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.bench_table2 import table2_suite
from benchmarks.common import Row
from repro.sim.suite import MonteCarloSuite, SampleSpace, TraceSuite
from repro.sim.sweep import run_sweep

CASES = int(os.environ.get("REPRO_BENCH_SWEEP_CASES", "60"))
REPEATS = int(os.environ.get("REPRO_BENCH_SWEEP_REPEATS", "3"))
OUT_PATH = "BENCH_sweep.json"


def _executors() -> tuple[str, ...]:
    from repro.core.engine import jax_available

    base = ("serial", "process", "vectorized")
    return base + ("jax",) if jax_available() else base


EXECUTORS = _executors()


def stress_suite(num_cases: int = CASES) -> TraceSuite:
    """Fixed execution-bound suite: fan-in heavy, event-dense, trace-frozen."""
    space = SampleSpace(
        codes=((14, 10),), cluster_sizes=(14,), chunk_mb=(1024.0,),
        regimes=("hot2s",), failure_patterns=("single",),
    )
    live = MonteCarloSuite("stress", num_cases, space,
                           schemes=("traditional", "ppr"), base_seed=17)
    return TraceSuite.freeze(live, num_epochs=256, name="stress_trace")


def _time_sweep(make_suite, executor: str) -> tuple[float, float]:
    """Best wall-clock of REPEATS runs plus the best run's planner
    wall-clock (summed `SimResult.planning_time` across cases/schemes —
    the batched engine charges each case its share of batch planning, so
    the totals are comparable across executors). Pool startup is timed
    too, so the process row honestly carries its spawn cost (or, below
    the spawn-amortization threshold, its serial fallback); repeats
    smooth cold-cache noise."""
    best, best_plan = float("inf"), 0.0
    for _ in range(REPEATS):
        suite = make_suite()
        t0 = time.perf_counter()
        sweep = run_sweep(suite, executor=executor)
        secs = time.perf_counter() - t0
        if secs < best:
            best = secs
            best_plan = sum(r.planning_time for c in sweep.cases
                            for r in c.results.values())
    return best, best_plan


def run() -> list[Row]:
    suites = {
        "table2_60": lambda: table2_suite(CASES),
        "table2_60_trace": lambda: TraceSuite.freeze(
            table2_suite(CASES), num_epochs=64),
        "stress_60_trace": stress_suite,
    }
    report: dict = {"cases": CASES, "suites": {}}
    rows: list[Row] = []
    for name, make in suites.items():
        entry: dict = {}
        serial_s = None
        for ex in EXECUTORS:
            secs, plan_s = _time_sweep(make, ex)
            entry[ex] = {
                "seconds": round(secs, 4),
                "cases_per_sec": round(CASES / secs, 2),
                # planner-time vs execution-time split: how much of the
                # sweep's wall-clock went to planning (schedulers + BMF
                # replanning) vs everything else (event stepping, glue)
                "planner_seconds": round(plan_s, 4),
                "exec_seconds": round(max(secs - plan_s, 0.0), 4),
                "planner_frac": round(plan_s / secs, 3),
            }
            if ex == "serial":
                serial_s = secs
            else:
                entry[ex]["speedup_vs_serial"] = round(serial_s / secs, 2)
            rows.append(Row(
                f"sweep/{name}/{ex}", secs * 1e6 / CASES,
                f"cases_per_sec={CASES / secs:.1f}"
                f" planner_frac={plan_s / secs:.2f}"
                + ("" if ex == "serial"
                   else f" speedup_vs_serial={serial_s / secs:.2f}x"),
            ))
        report["suites"][name] = entry
    vec = report["suites"]["stress_60_trace"]["vectorized"]
    report["vectorized_ge_5x_on_execution_bound"] = \
        vec["speedup_vs_serial"] >= 5.0
    # the array-native planner layer's headline: both planner-bound Table
    # II suites at >= 3x serial (aspirational bar from ISSUE 3; current
    # measurements land ~1.5-2x — the shared per-case scheduler + rng
    # floor caps the ratio, see docs/architecture.md "planner layer")
    report["vectorized_ge_3x_on_planner_bound"] = all(
        report["suites"][s]["vectorized"].get("speedup_vs_serial", 0) >= 3.0
        for s in ("table2_60", "table2_60_trace")
    )
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(Row("sweep/json", 0.0, f"wrote {OUT_PATH}"))
    return rows
