"""Paper Fig. 10: multi-node recovery — m-PPR vs random vs MSRepair.

Paper claims: MSRepair cuts 21.3% (RS(4,2)), 46.5% (RS(6,3)), 59.7%
(RS(7,4)) vs m-PPR; random ~ MSRepair at RS(4,2) (tiny NR set).

Declarative: one `GridSuite` over the three codes x 20 trials, executed
by a single `run_sweep` invocation.
"""
from benchmarks.common import (BENCH_EXECUTOR, TRIALS, Row, mininet_scenario,
                               reduction)
from repro.sim.suite import GridSuite
from repro.sim.sweep import run_sweep

SCHEMES = ("mppr", "random", "msrepair")
CODES = [(4, 2), (6, 3), (7, 4)]


def fig10_suite(trials=TRIALS) -> GridSuite:
    return GridSuite(
        "fig10",
        axes={"code": CODES},
        build=lambda p, seed: mininet_scenario(
            *p["code"], (0, 1), chunk_mb=32, seed=seed),
        trials=trials,
        schemes=SCHEMES,
    )


def run() -> list[Row]:
    sweep = run_sweep(fig10_suite(), executor=BENCH_EXECUTOR)
    groups = sweep.group_by("code")
    rows = []
    for (n, k) in CODES:
        cell = groups[((n, k),)]
        t_m = cell.stats("mppr").mean
        t_r = cell.stats("random").mean
        ms = cell.stats("msrepair")
        rows.append(Row(
            f"fig10/rs{n}{k}/32MB",
            ms.mean_planning * 1e6,
            f"mppr={t_m:.2f}s random={t_r:.2f}s msrepair={ms.mean:.2f}s "
            f"ms_vs_mppr=-{reduction(t_m, ms.mean):.1f}% "
            f"ms_vs_random=-{reduction(t_r, ms.mean):.1f}%",
        ))
    rows.append(Row(
        "fig10/summary", 0.0,
        f"overall ms_vs_mppr=-{sweep.reduction_pct('mppr', 'msrepair'):.1f}% "
        f"(paper: 21.3/46.5/59.7% by code)",
    ))
    return rows
