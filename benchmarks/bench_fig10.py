"""Paper Fig. 10: multi-node recovery — m-PPR vs random vs MSRepair.

Paper claims: MSRepair cuts 21.3% (RS(4,2)), 46.5% (RS(6,3)), 59.7%
(RS(7,4)) vs m-PPR; random ~ MSRepair at RS(4,2) (tiny NR set).
"""
from benchmarks.common import Row, mininet_scenario, reduction, run_trials

SCHEMES = ("mppr", "random", "msrepair")


def run() -> list[Row]:
    rows = []
    for (n, k) in [(4, 2), (6, 3), (7, 4)]:
        res = run_trials(
            lambda seed: mininet_scenario(n, k, (0, 1), chunk_mb=32,
                                          seed=seed),
            SCHEMES)
        t_m, _, _ = res["mppr"]
        t_r, _, _ = res["random"]
        t_s, _, plan_s = res["msrepair"]
        rows.append(Row(
            f"fig10/rs{n}{k}/32MB",
            plan_s * 1e6,
            f"mppr={t_m:.2f}s random={t_r:.2f}s msrepair={t_s:.2f}s "
            f"ms_vs_mppr=-{reduction(t_m, t_s):.1f}% "
            f"ms_vs_random=-{reduction(t_r, t_s):.1f}%",
        ))
    return rows
