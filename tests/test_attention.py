"""Flash attention (custom VJP) vs naive reference: fwd + grads, GQA,
windows, decode path, chunk invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def naive(q, k, v, q_pos, kv_pos, causal=True, window=0):
    b, tq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, tq, kvh, g, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qf, k.astype(jnp.float32)) / np.sqrt(hd)
    valid = kv_pos[:, None, None, None, :] >= 0
    if causal:
        valid &= kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window > 0:
        valid &= kv_pos[:, None, None, None, :] > (
            q_pos[:, None, None, :, None] - window)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, hd)


def _qkv(b=2, t=33, h=4, kv=2, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t)).astype(jnp.int32)
    return q, k, v, pos


@pytest.mark.parametrize("chunk", [4, 8, 33])
@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_forward_matches_naive(chunk, window, kv):
    q, k, v, pos = _qkv(kv=kv)
    o1 = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                             chunk=chunk)
    o2 = naive(q, k, v, pos, pos, window=window)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 0.03


@pytest.mark.parametrize("window", [0, 7])
def test_gradients_match_naive(window):
    q, k, v, pos = _qkv()
    f1 = lambda q, k, v: L.chunked_attention(
        q, k, v, q_pos=pos, kv_pos=pos, window=window, chunk=8
    ).astype(jnp.float32).sum()
    f2 = lambda q, k, v: naive(q, k, v, pos, pos, window=window).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 0.06


def test_traced_window_gradient():
    """Per-layer window arrives as a traced scalar under scan — grads must
    still flow (None cotangent path)."""
    q, k, v, pos = _qkv()

    def loss(q, w):
        return L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos,
                                   window=w, chunk=8).astype(jnp.float32).sum()
    g = jax.grad(loss)(q, jnp.asarray(7, jnp.int32))
    assert jnp.isfinite(g).all()


def test_decode_single_query_against_cache():
    q, k, v, pos = _qkv(t=32)
    # full attention last-token vs decode-style single query
    o_full = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=pos, chunk=8)
    o_dec = L.chunked_attention(q[:, -1:], k, v,
                                q_pos=pos[:, -1:], kv_pos=pos, chunk=8)
    assert float(jnp.max(jnp.abs(o_dec - o_full[:, -1:]))) < 1e-2


def test_invalid_positions_masked():
    """Cache slots with pos=-1 (unwritten) contribute nothing."""
    q, k, v, pos = _qkv(t=16)
    kv_pos = pos.at[:, 8:].set(-1)
    o1 = L.chunked_attention(q[:, :1], k, v, q_pos=pos[:, 15:16],
                             kv_pos=kv_pos, chunk=8)
    o2 = L.chunked_attention(q[:, :1], k[:, :8], v[:, :8],
                             q_pos=pos[:, 15:16], kv_pos=pos[:, :8], chunk=8)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-2


def test_fully_masked_rows_are_finite():
    q, k, v, pos = _qkv(t=8)
    kv_pos = jnp.full_like(pos, -1)
    o = L.chunked_attention(q, k, v, q_pos=pos, kv_pos=kv_pos, chunk=4)
    assert jnp.isfinite(o).all()
    assert float(jnp.max(jnp.abs(o))) < 1e-6
