"""Data-plane execution: every scheme's plan reconstructs exact bytes."""
import numpy as np
import pytest

from repro.core import executor, topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario
from repro.ec.rs import RSCode


def _run(n, k, failed, scheme, seed=0, cluster=None):
    cluster = cluster or n + 2
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=seed,
                           mode="markov")
    sc = Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                  bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=4.0)
    return RepairSimulator(sc).run(scheme)


@pytest.mark.parametrize("n,k", [(4, 2), (6, 3), (7, 4)])
@pytest.mark.parametrize("scheme", ["traditional", "ppr", "bmf"])
def test_single_failure_byte_exact(n, k, scheme, rng):
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 2048), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(n, k, (0,), scheme)
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified
    assert np.array_equal(ex.reconstructed[0], cw[0])


@pytest.mark.parametrize("n,k", [(6, 3), (7, 4)])
@pytest.mark.parametrize("scheme", ["mppr", "random", "msrepair"])
def test_multi_failure_byte_exact(n, k, scheme, rng):
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 1024), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(n, k, (0, 1), scheme)
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified


def test_parity_failure_repairs(rng):
    code = RSCode(6, 3)
    data = rng.integers(0, 256, size=(3, 512), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(6, 3, (4,), "bmf", seed=3)      # a parity node
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified


def test_relays_move_extra_bytes(rng):
    """A relayed plan moves more bytes than rounds*chunk (store&forward)."""
    code = RSCode(6, 3)
    data = rng.integers(0, 256, size=(3, 256), dtype=np.uint8)
    cw = code.encode(data)
    found = False
    for seed in range(25):
        res = _run(6, 3, (0,), "bmf", seed=seed, cluster=12)
        if res.relay_hops > 0:
            ex = executor.execute_plan(res.plan, code, cw)
            assert ex.verified
            direct = sum(len(r.transfers) for r in res.plan.rounds) * 256
            assert ex.bytes_moved > direct
            found = True
            break
    assert found, "no BMF relay found in 25 seeds — suspicious"
