"""Data-plane execution: every scheme's plan reconstructs exact bytes."""
import numpy as np
import pytest

from repro.core import executor, topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import RepairSimulator, Scenario
from repro.ec.rs import RSCode


def _run(n, k, failed, scheme, seed=0, cluster=None):
    cluster = cluster or n + 2
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=seed,
                           mode="markov")
    sc = Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                  bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=4.0)
    return RepairSimulator(sc).run(scheme)


@pytest.mark.parametrize("n,k", [(4, 2), (6, 3), (7, 4)])
@pytest.mark.parametrize("scheme", ["traditional", "ppr", "bmf"])
def test_single_failure_byte_exact(n, k, scheme, rng):
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 2048), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(n, k, (0,), scheme)
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified
    assert np.array_equal(ex.reconstructed[0], cw[0])


@pytest.mark.parametrize("n,k", [(6, 3), (7, 4)])
@pytest.mark.parametrize("scheme", ["mppr", "random", "msrepair"])
def test_multi_failure_byte_exact(n, k, scheme, rng):
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 1024), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(n, k, (0, 1), scheme)
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified


def test_parity_failure_repairs(rng):
    code = RSCode(6, 3)
    data = rng.integers(0, 256, size=(3, 512), dtype=np.uint8)
    cw = code.encode(data)
    res = _run(6, 3, (4,), "bmf", seed=3)      # a parity node
    ex = executor.execute_plan(res.plan, code, cw)
    assert ex.verified


def test_consumed_source_raises_clear_error(rng):
    """Store-and-forward consumes a source's buffer when it sends: a plan
    whose later round re-sources it is unexecutable and must fail loudly
    (the store.pop audit), not KeyError or silently move zeros."""
    from repro.core.plan import Job, RepairPlan, Round, Transfer

    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 64), dtype=np.uint8))
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    bad = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1}))]),
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1}))]),
    ])
    with pytest.raises(ValueError, match="holds no buffer"):
        executor.execute_plan(bad, code, cw, use_kernel=False)
    # validate_plan rejects the same plan up front — the executor
    # invariant is exactly "validate_plan-clean"
    from repro.core.plan import validate_plan

    with pytest.raises(ValueError):
        validate_plan(bad)


def test_source_refilled_across_rounds_is_fine(rng):
    """A node may send again in a later round once a new fragment arrived
    — consumption is per buffer, not per node."""
    from repro.core.plan import Job, RepairPlan, Round, Transfer, validate_plan

    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 64), dtype=np.uint8))
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    plan = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=2, dst=1, job=0,
                                  terms=frozenset({2}))]),
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1, 2}))]),
    ])
    validate_plan(plan)
    ex = executor.execute_plan(plan, code, cw, use_kernel=False)
    assert ex.verified
    assert ex.bytes_moved == 2 * 64


def test_bytes_moved_relay_accounting(rng):
    """Relays re-send whole chunks: a path of length L moves (L-1)*nbytes.
    Pinned exactly on a hand-built relayed plan (regression for the
    previously untested accounting)."""
    from repro.core.plan import Job, RepairPlan, Round, Transfer, validate_plan

    code = RSCode(4, 2)
    nbytes = 128
    cw = code.encode(rng.integers(0, 256, size=(2, nbytes), dtype=np.uint8))
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    plan = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[
            Transfer(src=1, dst=0, job=0, terms=frozenset({1})),
            # 2 -> 0 relayed through idle nodes 4 and 5: 3 hops
            Transfer(src=2, dst=0, job=0, terms=frozenset({2}),
                     path=(2, 4, 5, 0)),
        ]),
    ])
    validate_plan(plan, max_recv_per_round=2)
    ex = executor.execute_plan(plan, code, cw, use_kernel=False)
    assert ex.verified
    assert ex.bytes_moved == nbytes * (1 + 3)
    from repro.core.engine.dataplane import execute_plans_batch

    bat = execute_plans_batch([plan], [code], [cw], use_kernel=False)
    assert int(bat.bytes_moved[0]) == ex.bytes_moved


def test_execute_plan_block_of_placement(rng):
    """`block_of` decouples node ids from codeword positions: executing
    under a shifted placement reconstructs the placed block."""
    from repro.core.plan import Job, RepairPlan, Round, Transfer

    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 96), dtype=np.uint8))
    # node 10 holds block 0 (failed), nodes 11/12 blocks 1/2
    jobs = [Job(job_id=0, failed_node=10, requestor=10, helpers=(11, 12))]
    plan = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=11, dst=12, job=0,
                                  terms=frozenset({11}))]),
        Round(transfers=[Transfer(src=12, dst=10, job=0,
                                  terms=frozenset({11, 12}))]),
    ])
    block_of = np.full(13, -1, dtype=np.int64)
    block_of[[10, 11, 12]] = [0, 1, 2]
    ex = executor.execute_plan(plan, code, cw, use_kernel=False,
                               block_of=block_of)
    assert ex.verified
    assert np.array_equal(ex.reconstructed[0], cw[0])


def test_relays_move_extra_bytes(rng):
    """A relayed plan moves more bytes than rounds*chunk (store&forward)."""
    code = RSCode(6, 3)
    data = rng.integers(0, 256, size=(3, 256), dtype=np.uint8)
    cw = code.encode(data)
    found = False
    for seed in range(25):
        res = _run(6, 3, (0,), "bmf", seed=seed, cluster=12)
        if res.relay_hops > 0:
            ex = executor.execute_plan(res.plan, code, cw)
            assert ex.verified
            direct = sum(len(r.transfers) for r in res.plan.rounds) * 256
            assert ex.bytes_moved > direct
            found = True
            break
    assert found, "no BMF relay found in 25 seeds — suspicious"
