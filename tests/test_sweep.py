"""Sweep engine laws: determinism, single-scenario parity, executor
equivalence, and suite-generator shape/validity."""
import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel
from repro.core.simulator import (ALL_SCHEMES, RepairSimulator, Scenario,
                                  run_scheme)
from repro.ec.rs import RSCode
from repro.sim.suite import (FAILURE_PATTERNS, GridSuite, MonteCarloSuite,
                             SampleSpace, TraceSuite, VOLATILITY_REGIMES,
                             sample_failures)
from repro.sim.sweep import run_sweep


def _scenario(n=6, k=3, failed=(0,), seed=0, cluster=8, chunk=8.0):
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=seed, mode="markov")
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk)


def _small_mc_suite(base_seed=3, num=8):
    space = SampleSpace(
        codes=((4, 2), (6, 3)), cluster_sizes=(8,), chunk_mb=(8.0,),
        regimes=("hot2s",), failure_patterns=("single", "double", "rack"))
    return MonteCarloSuite("t", num, space, base_seed=base_seed)


# ------------------------------------------------------------- determinism
def test_sweep_deterministic_same_seed():
    a = run_sweep(_small_mc_suite(), executor="serial")
    b = run_sweep(_small_mc_suite(), executor="serial")
    assert len(a.cases) == len(b.cases)
    for ca, cb in zip(a.cases, b.cases):
        assert ca.params == cb.params and ca.seed == cb.seed
        assert set(ca.results) == set(cb.results)
        for s in ca.results:
            assert ca.results[s].total_time == cb.results[s].total_time
            assert ca.results[s].round_times == cb.results[s].round_times
            assert ca.results[s].relay_hops == cb.results[s].relay_hops


def test_sweep_different_seed_differs():
    a = run_sweep(_small_mc_suite(base_seed=3), executor="serial")
    b = run_sweep(_small_mc_suite(base_seed=4), executor="serial")
    ta = [c.results[s].total_time for c in a.cases for s in sorted(c.results)]
    tb = [c.results[s].total_time for c in b.cases for s in sorted(c.results)]
    assert ta != tb


# ------------------------------------------------- single-scenario parity
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_size_one_sweep_matches_simulate(scheme):
    """A sweep of size 1 is bit-identical to the legacy single-Scenario
    path, for every scheme (wall-clock planning_time excluded)."""
    failed = (0, 1) if scheme in ("mppr", "random", "msrepair") else (0,)
    seed = 5
    sc = _scenario(n=6, k=3, failed=failed, seed=seed)
    suite = GridSuite("one", axes={}, build=lambda p, s: sc,
                      trials=1, schemes=(scheme,), base_seed=seed)
    sweep = run_sweep(suite, executor="serial")
    direct = RepairSimulator(sc, random_seed=seed).run(scheme)
    [case] = sweep.cases
    got = case.results[scheme]
    assert got.total_time == direct.total_time
    assert got.round_times == direct.round_times
    assert got.relay_hops == direct.relay_hops
    assert got.num_rounds == direct.num_rounds


def test_run_scheme_is_simulator_run():
    sc = _scenario(seed=2)
    a = run_scheme(sc, "bmf", random_seed=2)
    b = RepairSimulator(sc, random_seed=2).run("bmf")
    assert a.total_time == b.total_time and a.round_times == b.round_times


# ------------------------------------------------------ executor equivalence
@pytest.mark.parametrize("executor", ["thread", "process"])
def test_executors_match_serial(executor):
    suite = _small_mc_suite(num=6)
    ref = run_sweep(suite, executor="serial")
    got = run_sweep(suite, executor=executor, max_workers=2)
    for cr, cg in zip(ref.cases, got.cases):
        assert set(cr.results) == set(cg.results)
        for s in cr.results:
            assert cr.results[s].total_time == cg.results[s].total_time
            assert cr.results[s].round_times == cg.results[s].round_times


def test_process_pool_path_matches_serial(monkeypatch):
    """Exercise the real ProcessPoolExecutor branch (chunked pool.map,
    Scenario pickling incl. the memoized jobs cache) by dropping the
    spawn-amortization threshold below the suite size."""
    import repro.sim.sweep as sweep_mod

    monkeypatch.setattr(sweep_mod, "_MIN_CASES_PER_WORKER", 1)
    suite = _small_mc_suite(num=6)
    ref = run_sweep(suite, executor="serial")
    got = run_sweep(suite, executor="process", max_workers=2)
    for cr, cg in zip(ref.cases, got.cases):
        assert set(cr.results) == set(cg.results)
        for s in cr.results:
            assert cr.results[s].total_time == cg.results[s].total_time
            assert cr.results[s].round_times == cg.results[s].round_times


def test_process_executor_spawn_amortization_fallback():
    """Below the spawn-amortization threshold the process executor must
    warn and fall back to serial (identical results) instead of paying
    ~0.5 s of worker start-up per handful of cases."""
    import repro.sim.sweep as sweep_mod

    suite = _small_mc_suite(num=6)
    ref = run_sweep(suite, executor="serial")
    with pytest.warns(RuntimeWarning, match="spawn"):
        got = run_sweep(suite, executor="process", max_workers=2)
    for cr, cg in zip(ref.cases, got.cases):
        for s in cr.results:
            assert cr.results[s].total_time == cg.results[s].total_time
    # worker sizing: never more workers than the threshold can feed
    assert sweep_mod._process_workers(6, None) == 0
    thresh = sweep_mod._MIN_CASES_PER_WORKER
    assert sweep_mod._process_workers(4 * thresh, None) <= 4


# ------------------------------------------------------- suite generators
def test_grid_suite_covers_product():
    built = []

    def build(params, seed):
        built.append((params["a"], params["b"], params["trial"], seed))
        return _scenario(seed=seed)

    suite = GridSuite("g", axes={"a": [1, 2], "b": ["x", "y", "z"]},
                      build=build, trials=2, schemes=("ppr",))
    cases = list(suite.cases())
    assert len(cases) == len(suite) == 2 * 3 * 2
    assert len({c.index for c in cases}) == len(cases)
    assert {(p[0], p[1]) for p in built} == {(a, b) for a in (1, 2)
                                            for b in ("x", "y", "z")}
    assert all(p[2] == p[3] for p in built)      # seed == base_seed + trial


def test_mc_suite_cases_valid_and_reproducible():
    suite = _small_mc_suite(num=16)
    cases = list(suite.cases())
    assert len(cases) == 16
    again = list(_small_mc_suite(num=16).cases())
    for c, c2 in zip(cases, again):
        assert c.params == c2.params and c.seed == c2.seed
        sc = c.scenario
        n, k = c.params["code"]
        assert sc.code.n == n and sc.code.k == k
        assert sc.num_nodes >= n
        assert sc.bw.base.shape == (sc.num_nodes, sc.num_nodes)
        assert all(0 <= f < n for f in sc.failed)
        assert 1 <= len(sc.failed) <= n - k
        assert c.params["regime"] in VOLATILITY_REGIMES
        assert c.params["pattern"] in FAILURE_PATTERNS
        # scheme sets match failure cardinality
        if len(sc.failed) > 1:
            assert c.schemes == ("mppr", "random", "msrepair")
        else:
            assert c.schemes == ("traditional", "ppr", "ppt", "bmf")
    # all runnable end-to-end
    sweep = run_sweep(suite, executor="serial")
    for c in sweep.cases:
        for s, r in c.results.items():
            assert r.total_time > 0 and np.isfinite(r.total_time), s


def test_mc_suite_prefix_stable():
    """Case i is identical no matter the suite size (counter-based seeds)."""
    big = list(_small_mc_suite(num=10).cases())
    small = list(_small_mc_suite(num=4).cases())
    for c_small, c_big in zip(small, big):
        assert c_small.params == c_big.params and c_small.seed == c_big.seed


def test_sample_failures_patterns():
    rng = np.random.default_rng(0)
    for _ in range(50):
        (f,) = sample_failures(rng, 7, 4, "single")
        assert 0 <= f < 7
        d = sample_failures(rng, 7, 4, "double")
        assert len(set(d)) == 2 and all(0 <= f < 7 for f in d)
        r = sample_failures(rng, 7, 4, "rack", rack_size=4)
        assert 1 <= len(r) <= 2 and all(0 <= f < 7 for f in r)
        racks = {f // 4 for f in r}
        assert len(racks) == 1                      # correlated: one rack
    with pytest.raises(ValueError):
        sample_failures(rng, 4, 3, "double")        # n - k < 2
    with pytest.raises(ValueError):
        sample_failures(rng, 4, 2, "nope")


def test_sample_space_validation():
    with pytest.raises(ValueError):
        SampleSpace(codes=((3, 3),))
    with pytest.raises(ValueError):
        SampleSpace(regimes=("warm9s",))
    with pytest.raises(ValueError):
        SampleSpace(failure_patterns=("cascade",))


def test_trace_suite_freeze_reproduces():
    suite = _small_mc_suite(num=4)
    frozen = TraceSuite.freeze(suite, num_epochs=64)
    assert len(frozen) == len(suite)
    for case in frozen.cases():
        assert isinstance(case.scenario.bw, BandwidthTrace)
    # within the recorded window the frozen sweep matches the live one
    live = run_sweep(suite, executor="serial")
    replay = run_sweep(frozen, executor="serial")
    for cl, cr in zip(live.cases, replay.cases):
        for s in cl.results:
            if max(cl.results[s].round_times, default=0) == 0:
                continue
            # identical as long as the repair finished inside the recording
            if cl.results[s].total_time < 64 * 2.0:
                assert cl.results[s].total_time == cr.results[s].total_time


# ------------------------------------------------------------- aggregation
def test_sweep_result_stats_and_cdf():
    suite = GridSuite(
        "agg", axes={"chunk_mb": [4.0, 8.0]},
        build=lambda p, seed: _scenario(seed=seed, chunk=p["chunk_mb"]),
        trials=3, schemes=("ppr", "bmf"))
    sweep = run_sweep(suite, executor="serial")
    assert len(sweep.cases) == 6
    st = sweep.stats("bmf")
    t = sweep.times("bmf")
    assert st.count == 6
    assert st.mean == pytest.approx(float(t.mean()))
    assert st.min <= st.p50 <= st.p90 <= st.max
    spd, cdf = sweep.speedup_cdf("ppr", "bmf")
    assert len(spd) == 6 and np.all(np.diff(spd) >= 0)
    assert cdf[-1] == 1.0
    assert (spd >= 1.0 - 1e-9).all()   # static-per-round BMF never loses to PPR here
    groups = sweep.group_by("chunk_mb")
    assert set(groups) == {(4.0,), (8.0,)}
    assert all(len(g.cases) == 3 for g in groups.values())
    red = sweep.reduction_pct("ppr", "bmf")
    assert np.isfinite(red)
    assert sweep.summary_table()


# ------------------------------------------------------- auto resolution
def test_auto_resolves_to_vectorized_on_cpu():
    """"auto" = the batched array engine; on a CPU jax backend (or no
    jax) the tuned numpy engine always wins, live or trace, any size."""
    from repro.sim.sweep import _resolve_executor

    live = list(_small_mc_suite().cases())
    assert _resolve_executor("auto", live) == "vectorized"
    frozen = list(TraceSuite.freeze(_small_mc_suite()).cases())
    assert _resolve_executor("auto", frozen) == "vectorized"
    # explicit choices pass through untouched
    assert _resolve_executor("serial", live) == "serial"
    assert _resolve_executor("jax", live) == "jax"


def test_auto_picks_jax_on_accelerator_for_large_trace_suites(monkeypatch):
    """With a device backend, auto routes large trace-frozen suites to
    the jax executor — and only those: live epochs or small suites stay
    on the numpy engine (jit compile + dispatch dominate there, the
    BENCH_sweep table2_60 regression)."""
    jax = pytest.importorskip("jax")
    from repro.sim import sweep as sweep_mod

    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    big = list(TraceSuite.freeze(
        _small_mc_suite(num=sweep_mod._JAX_AUTO_MIN_CASES)).cases())
    assert sweep_mod._resolve_executor("auto", big) == "jax"
    small = big[: sweep_mod._JAX_AUTO_MIN_CASES - 1]
    assert sweep_mod._resolve_executor("auto", small) == "vectorized"
    live = list(_small_mc_suite(num=sweep_mod._JAX_AUTO_MIN_CASES).cases())
    assert sweep_mod._resolve_executor("auto", live) == "vectorized"


def test_auto_sweep_matches_serial():
    suite = _small_mc_suite(num=4)
    ref = run_sweep(suite, executor="serial")
    got = run_sweep(_small_mc_suite(num=4), executor="auto")
    for ca, cb in zip(ref.cases, got.cases):
        for s in ca.results:
            assert abs(ca.results[s].total_time
                       - cb.results[s].total_time) <= 1e-9


# ------------------------------------------------------- byte verification
def test_verify_bytes_samples_and_passes():
    """`verify_bytes=k` byte-verifies k sampled cases against placed
    stripes — every scheme of every sampled case, batched."""
    suite = _small_mc_suite(num=6)
    sweep = run_sweep(suite, executor="vectorized", verify_bytes=3)
    bv = sweep.byte_verification
    assert bv is not None and bv.verified and not bv.failures
    checked_cases = {i for i, _ in bv.checked}
    assert len(checked_cases) == 3
    # every scheme of each sampled case was executed over bytes
    by_case = {c.index: set(c.results) for c in sweep.cases}
    for i in checked_cases:
        assert {s for j, s in bv.checked if j == i} == by_case[i]
    assert bv.nbytes > 0


def test_verify_bytes_covers_ppt_and_multi():
    """Single-failure suites include ppt (via the pipeline-tree
    lowering); the sample covers it."""
    space = SampleSpace(codes=((6, 3),), cluster_sizes=(9,),
                        chunk_mb=(8.0,), regimes=("hot2s",),
                        failure_patterns=("single",))
    suite = MonteCarloSuite("bv1", 3, space, base_seed=11)
    sweep = run_sweep(suite, executor="serial", verify_bytes=3)
    bv = sweep.byte_verification
    assert bv.verified
    assert any(s == "ppt" for _, s in bv.checked)


def test_verify_bytes_off_by_default():
    sweep = run_sweep(_small_mc_suite(num=2), executor="serial")
    assert sweep.byte_verification is None
