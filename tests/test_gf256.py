"""GF(256) field axioms (hypothesis) + table cross-checks."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ec import gf256

elem = st.integers(0, 255)
nz = st.integers(1, 255)


@given(elem, elem)
@settings(max_examples=80)
def test_mul_matches_peasant(a, b):
    assert int(gf256.gf_mul(a, b)) == gf256.gf_mul_slow(a, b)


@given(elem, elem)
@settings(max_examples=50)
def test_commutative(a, b):
    assert int(gf256.gf_mul(a, b)) == int(gf256.gf_mul(b, a))


@given(elem, elem, elem)
@settings(max_examples=50)
def test_associative(a, b, c):
    ab_c = gf256.gf_mul(gf256.gf_mul(a, b), c)
    a_bc = gf256.gf_mul(a, gf256.gf_mul(b, c))
    assert int(ab_c) == int(a_bc)


@given(elem, elem, elem)
@settings(max_examples=50)
def test_distributive(a, b, c):
    left = gf256.gf_mul(a, b ^ c)
    right = int(gf256.gf_mul(a, b)) ^ int(gf256.gf_mul(a, c))
    assert int(left) == right


@given(nz)
@settings(max_examples=60)
def test_inverse(a):
    assert int(gf256.gf_mul(a, gf256.gf_inv(a))) == 1


@given(elem)
def test_identities(a):
    assert int(gf256.gf_mul(a, 1)) == a
    assert int(gf256.gf_mul(a, 0)) == 0


@given(nz, st.integers(0, 8))
@settings(max_examples=40)
def test_pow(a, n):
    want = 1
    for _ in range(n):
        want = gf256.gf_mul_slow(want, a)
    assert gf256.gf_pow(a, n) == want


def test_exp_log_roundtrip():
    for a in range(1, 256):
        assert gf256.EXP_TABLE[gf256.LOG_TABLE[a]] == a


@given(nz)
@settings(max_examples=40)
def test_mul_bitmatrix_semantics(c):
    """out_bit[i] = XOR_j M[i,j] & in_bit[j]  must equal table multiply."""
    m = gf256.mul_bitmatrix(c)
    for x in (1, 2, 37, 128, 200, 255):
        bits_in = [(x >> j) & 1 for j in range(8)]
        out = 0
        for i in range(8):
            bit = 0
            for j in range(8):
                bit ^= m[i, j] & bits_in[j]
            out |= bit << i
        assert out == gf256.gf_mul_slow(c, x)


def test_matrix_inverse_roundtrip(rng):
    from repro.ec.gf256 import gf_mat_inv, MUL_TABLE
    for n in (2, 3, 5):
        while True:
            m = rng.integers(0, 256, size=(n, n)).astype(np.uint8)
            try:
                inv = gf_mat_inv(m)
                break
            except np.linalg.LinAlgError:
                continue
        prod = np.zeros((n, n), dtype=np.uint8)
        for i in range(n):
            for j in range(n):
                acc = 0
                for k in range(n):
                    acc ^= MUL_TABLE[m[i, k], inv[k, j]]
                prod[i, j] = acc
        assert np.array_equal(prod, np.eye(n, dtype=np.uint8))
