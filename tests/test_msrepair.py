"""Paper Table II reproduction + MSRepair scheduling properties."""
from repro.core.msrepair import plan_mppr, plan_msrepair, plan_random
from repro.core.plan import Job, validate_plan

# Paper's RS(7,4) scenario (1-indexed n1..n7 -> 0-indexed): failed {n1,n2},
# helpers R^1 = {n3,n4,n5,n6}, R^2 = {n4,n5,n6,n7}.
JOBS = [
    Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3, 4, 5)),
    Job(job_id=1, failed_node=1, requestor=1, helpers=(3, 4, 5, 6)),
]


def test_table2_msrepair_three_timestamps():
    plan = plan_msrepair(JOBS)
    validate_plan(plan)
    assert plan.num_rounds == 3        # paper Table II


def test_table2_mppr_six_timestamps():
    plan = plan_mppr(JOBS)
    validate_plan(plan)
    assert plan.num_rounds == 6        # paper Table II


def test_table2_random_between():
    """Paper's random schedule takes 4; any seed must land in [3, 6]."""
    for seed in range(12):
        plan = plan_random(JOBS, seed=seed)
        validate_plan(plan)
        assert 3 <= plan.num_rounds <= 8


def test_msrepair_reduction_percentages():
    """Paper: MSRepair cuts timestamps 50% vs m-PPR, 25% vs random (Table
    II: 3 vs 6 vs 4)."""
    ms = plan_msrepair(JOBS).num_rounds
    mp = plan_mppr(JOBS).num_rounds
    assert 1 - ms / mp >= 0.49


def test_priority_respected_in_round1():
    """Round 1 must contain {R,R} merges before any {R,RP} delivery; the
    paper's ts1 has two R-merges + one NR->RP delivery."""
    plan = plan_msrepair(JOBS)
    r_set = {3, 4, 5}
    nr_set = {2, 6}
    kinds = []
    for t in plan.rounds[0].transfers:
        src_cls = "R" if t.src in r_set else "NR"
        dst_cls = ("RP" if t.dst in (0, 1) else
                   "R" if t.dst in r_set else "NR")
        kinds.append((src_cls, dst_cls))
    assert ("R", "R") in kinds
    assert ("NR", "RP") in kinds
    assert ("NR", "R") not in kinds    # lowest priority never needed here


def test_rs63_multi_node_counts():
    """Paper Fig. 5: RS(6,3) two failures — m-PPR 4 ts, MSRepair 3 ts."""
    jobs = [
        Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3, 4)),
        Job(job_id=1, failed_node=1, requestor=1, helpers=(3, 4, 5)),
    ]
    assert plan_mppr(jobs).num_rounds == 4
    assert plan_msrepair(jobs).num_rounds == 3
