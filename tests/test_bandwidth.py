"""Bandwidth process + Fig. 2 ingress model properties."""
import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel


def test_static_process():
    m = topology.uniform_matrix(4, 10.0)
    p = BandwidthProcess(base=m, change_interval=None)
    assert np.array_equal(p.matrix_at(0.0), m)
    assert np.array_equal(p.matrix_at(123.4), m)
    assert p.epoch_end(5.0) == np.inf


@pytest.mark.parametrize("mode", ["jitter", "redraw", "markov"])
def test_process_deterministic_and_epochwise(mode):
    m = topology.heterogeneous_matrix(5, seed=1)
    p = BandwidthProcess(base=m, change_interval=2.0, seed=7, mode=mode)
    a = p.matrix_at(3.0)
    b = p.matrix_at(3.9)      # same epoch
    c = p.matrix_at(4.1)      # next epoch
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    # pure / history-free random access
    p2 = BandwidthProcess(base=m, change_interval=2.0, seed=7, mode=mode)
    assert np.array_equal(p2.matrix_at(3.5), a)
    assert (a[~np.eye(5, dtype=bool)] >= p.min_bw).all()
    assert (np.diag(a) == 0).all()


def test_markov_correlation_decays():
    m = topology.uniform_matrix(6, 20.0)
    p = BandwidthProcess(base=m, change_interval=1.0, seed=3, mode="markov",
                         rho=0.8, sigma=0.5)
    mats = [np.log(p.matrix_at(t + 0.5)[0, 1] / 20.0) for t in range(400)]
    x = np.array(mats)
    r1 = np.corrcoef(x[:-1], x[1:])[0, 1]
    r10 = np.corrcoef(x[:-10], x[10:])[0, 1]
    assert r1 > 0.55            # one-epoch memory ~ rho
    assert abs(r10) < r1 - 0.2  # decayed at lag 10


@pytest.mark.parametrize("mode,kw", [
    ("jitter", {}),
    ("redraw", {}),
    ("markov", {"sigma": 1.0, "rho": 0.9}),
])
def test_sample_epochs_matches_matrix_at(mode, kw):
    """Batched sampling is bit-identical to per-epoch random access,
    including across the markov AR-window truncation boundary."""
    m = topology.heterogeneous_matrix(6, seed=2)
    p = BandwidthProcess(base=m, change_interval=2.0, seed=11, mode=mode, **kw)
    horizon = BandwidthProcess._AR_HORIZON
    batch = p.sample_epochs(horizon + 10)
    for e in range(horizon + 10):
        assert np.array_equal(batch[e], p.matrix_at(e * 2.0 + 0.5)), (mode, e)
    offset = p.sample_epochs(6, start_epoch=horizon + 2)
    assert np.array_equal(offset, batch[horizon + 2:horizon + 8])


def test_sample_epochs_static():
    m = topology.uniform_matrix(4, 10.0)
    p = BandwidthProcess(base=m, change_interval=None)
    batch = p.sample_epochs(3)
    assert batch.shape == (3, 4, 4)
    assert np.array_equal(batch[2], m)


def test_epoch_cache_is_transparent():
    m = topology.heterogeneous_matrix(5, seed=4)
    p = BandwidthProcess(base=m, change_interval=2.0, seed=9, mode="markov")
    fresh = BandwidthProcess(base=m, change_interval=2.0, seed=9, mode="markov")
    a = p.matrix_at(6.5)
    _ = [p.matrix_at(t) for t in (0.1, 2.2, 4.9, 6.6, 6.9)]
    assert np.array_equal(p.matrix_at(6.5), a)
    assert np.array_equal(fresh.matrix_at(6.5), a)


def test_trace_replays_recorded_process():
    m = topology.heterogeneous_matrix(5, seed=3)
    p = BandwidthProcess(base=m, change_interval=2.0, seed=5, mode="markov")
    tr = BandwidthTrace.record(p, 8)
    for e in range(8):
        assert np.array_equal(tr.matrix_at(e * 2.0 + 1.0), p.matrix_at(e * 2.0 + 1.0))
    # epoch bookkeeping matches the source process inside the recording
    assert tr.epoch_of(5.0) == p.epoch_of(5.0)
    assert tr.epoch_end(5.0) == p.epoch_end(5.0)


def test_trace_cycle_and_clamp():
    m = topology.heterogeneous_matrix(4, seed=6)
    p = BandwidthProcess(base=m, change_interval=1.0, seed=2, mode="redraw")
    cyc = BandwidthTrace.record(p, 4, cycle=True)
    assert np.array_equal(cyc.matrix_at(5.5), cyc.matrix_at(1.5))   # 5 % 4 = 1
    clamp = BandwidthTrace.record(p, 4, cycle=False)
    assert np.array_equal(clamp.matrix_at(99.0), clamp.matrix_at(3.5))


def test_trace_validates_shape():
    with pytest.raises(ValueError):
        BandwidthTrace(epochs=np.zeros((3, 2)), change_interval=1.0)
    with pytest.raises(ValueError):
        BandwidthTrace(epochs=np.zeros((2, 3, 3)), change_interval=0.0)


def test_ingress_single_link_identity():
    ing = IngressModel(seed=0)
    bw = np.array([17.0])
    assert np.array_equal(ing.effective_rates(bw, 0, 0), bw)


def test_ingress_total_degrades_with_fanin():
    """Fig. 2: total ingress throughput trends down as links increase."""
    ing = IngressModel(seed=0)
    totals = []
    for m in range(1, 7):
        bw = np.full(m, 50.0)
        eff = ing.effective_rates(bw, 0, 0)
        totals.append(eff.sum())
    assert totals[0] == 50.0
    # degraded cap: total factor decreases monotonically
    for m in range(2, 7):
        assert ing.total_factor(m) < ing.total_factor(m - 1) or \
            ing.total_factor(m) == ing.floor
    # and the realized split is uneven (Fig. 2)
    eff6 = ing.effective_rates(np.full(6, 50.0), 0, 0)
    assert eff6.max() > 2.0 * eff6.min()


def test_ingress_persistent_shares():
    ing = IngressModel(seed=0, persistent_shares=True)
    a = ing.effective_rates(np.full(3, 30.0), receiver=2, epoch=0)
    b = ing.effective_rates(np.full(3, 30.0), receiver=2, epoch=9)
    assert np.array_equal(a, b)


def test_duplex_penalty():
    ing = IngressModel(seed=0)
    rates = ing.node_allocations(
        np.array([40.0, 40.0]), ("rx", "tx"), node=1, epoch=0)
    assert (rates <= 40.0 * ing.duplex + 1e-9).all()
    rates_rx = ing.node_allocations(
        np.array([40.0]), ("rx",), node=1, epoch=0)
    assert rates_rx[0] == 40.0


def test_paper_matrices():
    cl, bw = topology.aliyun_matrix()
    assert bw.shape == (6, 6) and cl.name(0) == "Beijing"
    assert bw[0, 1] == 59.669 and bw[5, 0] == 20.347
    cl1, bw1 = topology.table1_matrix()
    assert bw1[3, 2] == 20.0   # P3 -> P2, the paper's standout link
