"""Batched data-plane primitives: grid-driven kernel entry points
(`kernels.ops.gf256_scale_batch` / `xor_reduce_segments`), the lockstep
GF(256) Gauss-Jordan, and `RSCode.repair_coeffs_batch`.

Separate from tests/test_kernels.py and tests/test_rs.py on purpose:
those modules skip entirely without hypothesis, while everything here is
deterministic and must run on the bare-numpy tier-1 environment too.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ec import gf256
from repro.ec.rs import RSCode
from repro.kernels import ops


# ------------------------------------------------------- gf256_scale_batch
@pytest.mark.parametrize("m,nbytes", [(1, 32), (5, 100), (16, 1024)])
def test_gf256_scale_batch_paths(m, nbytes, rng):
    """Batched per-row premultiply: numpy ref path and grid-driven kernel
    path (interpret) both equal the per-row table ground truth."""
    coeffs = rng.integers(0, 256, size=m, dtype=np.uint8)
    data = rng.integers(0, 256, size=(m, nbytes), dtype=np.uint8)
    want = np.stack([gf256.MUL_TABLE[coeffs[i], data[i]] for i in range(m)])
    got_ref = np.asarray(ops.gf256_scale_batch(coeffs, data,
                                               use_kernel=False))
    got_kernel = np.asarray(ops.gf256_scale_batch(
        coeffs, data, use_kernel=True, interpret=True))
    assert np.array_equal(got_ref, want)
    assert np.array_equal(got_kernel, want)
    # also matches m calls of the (m, k) matmul entry point
    per_row = np.concatenate([
        np.asarray(ops.gf256_matmul(coeffs[i: i + 1, None],
                                    jnp.asarray(data[i: i + 1]),
                                    use_kernel=False))
        for i in range(m)
    ])
    assert np.array_equal(per_row, want)


def test_gf256_scale_batch_zero_and_one_coeffs(rng):
    data = rng.integers(0, 256, size=(3, 64), dtype=np.uint8)
    coeffs = np.array([0, 1, 255], dtype=np.uint8)
    out = np.asarray(ops.gf256_scale_batch(coeffs, data, use_kernel=False))
    assert not out[0].any()
    assert np.array_equal(out[1], data[1])


# ---------------------------------------------------- xor_reduce_segments
@pytest.mark.parametrize("nbytes", [4, 96, 1000])
def test_xor_reduce_segments_paths(nbytes, rng):
    """Segment XOR-fold: ragged groups (-1 padded), both paths, vs a
    plain python fold."""
    chunks = rng.integers(0, 256, size=(7, nbytes), dtype=np.uint8)
    groups = np.array([
        [0, 1, 2, -1],
        [3, -1, -1, -1],
        [4, 5, -1, -1],
        [6, 2, 0, 1],     # rows may repeat across groups
    ])
    want = np.stack([
        np.bitwise_xor.reduce(chunks[[r for r in g if r >= 0]], axis=0)
        for g in groups
    ])
    got_ref = np.asarray(ops.xor_reduce_segments(chunks, groups,
                                                 use_kernel=False))
    got_kernel = np.asarray(ops.xor_reduce_segments(
        chunks, groups, use_kernel=True, interpret=True))
    assert np.array_equal(got_ref, want)
    assert np.array_equal(got_kernel, want)


def test_xor_reduce_segments_empty(rng):
    chunks = rng.integers(0, 256, size=(3, 16), dtype=np.uint8)
    out = np.asarray(ops.xor_reduce_segments(
        chunks, np.zeros((0, 2), dtype=np.int64)))
    assert out.shape == (0, 16)


# ------------------------------------------------------ batched Gauss-Jordan
def test_gf_mat_inv_batch_matches_scalar(rng):
    for n in (2, 3, 4, 6):
        mats = []
        while len(mats) < 8:
            m = rng.integers(0, 256, size=(n, n)).astype(np.uint8)
            try:
                gf256.gf_mat_inv(m)
            except np.linalg.LinAlgError:
                continue
            mats.append(m)
        batch = gf256.gf_mat_inv_batch(np.stack(mats))
        for i, m in enumerate(mats):
            assert np.array_equal(batch[i], gf256.gf_mat_inv(m))


def test_gf_mat_inv_batch_singular_raises():
    good = np.eye(3, dtype=np.uint8)
    bad = np.zeros((3, 3), dtype=np.uint8)   # singular member
    with pytest.raises(np.linalg.LinAlgError):
        gf256.gf_mat_inv_batch(np.stack([good, bad]))


def test_gf_inv_np_vectorized():
    a = np.arange(1, 256, dtype=np.uint8)
    inv = gf256.gf_inv_np(a)
    assert all(int(inv[i]) == gf256.gf_inv(int(a[i]))
               for i in range(0, 255, 17))
    with pytest.raises(ZeroDivisionError):
        gf256.gf_inv_np(np.array([0], dtype=np.uint8))


# ---------------------------------------------------- repair_coeffs_batch
def test_repair_coeffs_batch_matches_scalar(rng):
    """Batched coefficients equal the scalar Gauss-Jordan row for row,
    for random (failed, helper-set) draws across several codes."""
    for n, k in [(4, 2), (6, 3), (7, 4), (9, 6)]:
        code = RSCode(n, k)
        failed, helpers = [], []
        for _ in range(12):
            f = int(rng.integers(n))
            hs = [x for x in range(n) if x != f]
            picks = rng.choice(len(hs), size=k, replace=False)
            failed.append(f)
            helpers.append([hs[int(i)] for i in picks])
        batch = code.repair_coeffs_batch(np.array(failed), np.array(helpers))
        assert batch.shape == (12, k) and batch.dtype == np.uint8
        for j in range(12):
            want = code.repair_coeffs((failed[j],), tuple(helpers[j]))[0]
            assert np.array_equal(batch[j], want)


def test_repair_coeffs_batch_validates():
    code = RSCode(6, 3)
    with pytest.raises(ValueError, match="helpers must be"):
        code.repair_coeffs_batch(np.array([0]), np.array([[1, 2]]))
    with pytest.raises(ValueError, match="overlap"):
        code.repair_coeffs_batch(np.array([0]), np.array([[0, 1, 2]]))
    out = code.repair_coeffs_batch(np.zeros(0, dtype=int),
                                   np.zeros((0, 3), dtype=int))
    assert out.shape == (0, 3)


def test_repair_coeffs_batch_reconstructs(rng):
    """Coefficients from the batch API actually repair bytes."""
    code = RSCode(7, 4)
    data = rng.integers(0, 256, size=(4, 128), dtype=np.uint8)
    cw = code.encode(data)
    failed = np.array([0, 2, 6])
    helpers = np.array([[1, 2, 3, 4], [0, 1, 3, 5], [0, 1, 2, 3]])
    coeffs = code.repair_coeffs_batch(failed, helpers)
    for j in range(3):
        got = np.bitwise_xor.reduce(
            gf256.MUL_TABLE[coeffs[j][:, None], cw[helpers[j]]], axis=0)
        assert np.array_equal(got, cw[failed[j]])
