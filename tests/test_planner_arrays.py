"""Array-native planner layer: batched search/scheduler parity + mutation.

Pins the planner layer three ways:
 * the batched BMF path search against brute-force enumeration (the same
   oracle the scalar DFS is pinned to),
 * the tuple/batched schedulers against in-test re-implementations of the
   historical object walks (candidates recomputed after every pick),
 * the whole batched planner, end to end, against the object planners
   across every scheme and all three volatility regimes.
"""
import itertools

import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.bmf import optimize_round, path_time
from repro.core.engine.arrays import (UnsupportedPlanError, decompile,
                                      splice_path, validate_plan_arrays)
from repro.core.engine.planner_arrays import (RANDOM_SCHEDULE_VERSION,
                                              find_min_time_paths_batch,
                                              hop_time_stack,
                                              lower_schedules_batch,
                                              msrepair_schedule,
                                              msrepair_schedule_batch,
                                              optimize_round_batch,
                                              plan_arrays_for_scheme,
                                              random_schedule,
                                              schedule_for_scheme)
from repro.core.engine.vectorized import run_scheme_vectorized
from repro.core.msrepair import select_helpers_multi
from repro.core.plan import FragmentState, Job, Round, Transfer
from repro.core.simulator import ALL_SCHEMES, Scenario, plan_for_scheme, run_scheme
from repro.ec.rs import RSCode

RTOL = 1e-6


# ------------------------------------------------------- batched BMF search
def brute_force_best(src, dst, idle, bw, chunk):
    """Oracle: enumerate every relay permutation of every subset."""
    best = (src, dst)
    best_t = path_time(best, bw, chunk)
    for r in range(1, len(idle) + 1):
        for subset in itertools.permutations(idle, r):
            path = (src, *subset, dst)
            t = path_time(path, bw, chunk)
            if t < best_t:
                best, best_t = path, t
    return best, best_t


def _search_one(src, dst, idle, bw, chunk, bound):
    n = bw.shape[0]
    avail = np.zeros((1, n), dtype=bool)
    avail[0, idle] = True
    w = hop_time_stack(bw[None], np.array([chunk]))
    paths, times, improved = find_min_time_paths_batch(
        np.array([src]), np.array([dst]), avail, w, np.array([bound]),
        bw_stack=bw[None], chunk_mb=np.array([chunk]))
    return paths[0], float(times[0]), bool(improved[0])


def test_batched_search_property_vs_bruteforce():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 500), st.integers(4, 7))
    @settings(max_examples=60, deadline=None)
    def check(seed, n):
        bw = topology.heterogeneous_matrix(n, low=1, high=30, seed=seed)
        idle = list(range(2, n))
        want_path, want_t = brute_force_best(0, 1, idle, bw, 16.0)
        got_path, got_t, _ = _search_one(0, 1, idle, bw, 16.0, np.inf)
        assert abs(got_t - want_t) < 1e-9
        assert abs(path_time(got_path, bw, 16.0) - want_t) < 1e-9

    check()


@pytest.mark.parametrize("seed", range(12))
def test_batched_search_vs_bruteforce_deterministic(seed):
    """Non-hypothesis twin of the property test (runs on bare installs):
    clusters <= 7 nodes, full permutation oracle, tie-heavy variant."""
    for n in (5, 7):
        bw = topology.heterogeneous_matrix(n, low=1, high=30, seed=seed)
        if seed % 3 == 0:
            bw = np.round(bw / 6) * 6
        idle = list(range(2, n))
        want_path, want_t = brute_force_best(0, 1, idle, bw, 16.0)
        got_path, got_t, _ = _search_one(0, 1, idle, bw, 16.0, np.inf)
        assert got_path == want_path or abs(got_t - want_t) < 1e-12
        assert abs(path_time(got_path, bw, 16.0) - want_t) < 1e-9


def test_batched_search_deep_optimum_falls_back_to_dfs():
    """A 4-relay optimum exceeds the enumeration depth; the Bellman-Ford
    certificate must detect it and the scalar fallback must return it."""
    n = 7
    bw = np.full((n, n), 0.1)
    np.fill_diagonal(bw, 0.0)
    for u, v in [(0, 2), (2, 3), (3, 4), (4, 5), (5, 1)]:
        bw[u, v] = 1000.0
    path, t, improved = _search_one(0, 1, [2, 3, 4, 5, 6], bw, 16.0, np.inf)
    assert path == (0, 2, 3, 4, 5, 1) and improved
    assert t == pytest.approx(path_time(path, bw, 16.0))


def test_batched_search_respects_bound():
    bw = topology.uniform_matrix(5, 10.0)
    path, t, improved = _search_one(0, 1, [2, 3, 4], bw, 10.0, 0.5)
    assert path == (0, 1) and not improved and t == 0.5


def test_optimize_round_batch_matches_object():
    rng = np.random.default_rng(5)
    for trial in range(40):
        n = int(rng.integers(8, 14))
        bw = topology.heterogeneous_matrix(n, low=1, high=40, seed=trial)
        if trial % 4 == 0:
            bw = np.round(bw / 6) * 6          # force rate ties
        pairs = [(1, 0), (3, 2)]
        rnd = Round(transfers=[
            Transfer(src=s, dst=d, job=0, terms=frozenset({s}))
            for s, d in pairs])
        idle = [x for x in range(n) if x not in {0, 1, 2, 3}]
        for opt_all in (False, True):
            ref, stats = optimize_round(rnd, bw, list(idle), 16.0,
                                        optimize_all=opt_all)
            T = len(pairs)
            hop_u = np.zeros((1, T, 1), dtype=np.int64)
            hop_v = np.zeros_like(hop_u)
            n_hops = np.ones((1, T), dtype=np.int64)
            for i, (s, d) in enumerate(pairs):
                hop_u[0, i, 0], hop_v[0, i, 0] = s, d
            avail = np.zeros((1, n), dtype=bool)
            avail[0, idle] = True
            hu, hv, bstats, _ = optimize_round_batch(
                hop_u, hop_v, n_hops, bw[None], np.array([16.0]), avail,
                optimize_all=opt_all)
            for i, tr in enumerate(ref.transfers):
                nh = int(n_hops[0, i])
                got = tuple(int(x) for x in hu[0, i, :nh]) \
                    + (int(hv[0, i, nh - 1]),)
                assert got == tr.path, (trial, opt_all, i)
            assert int(bstats.improved_links[0]) == stats.improved_links
            assert float(bstats.time_saved[0]) == stats.time_saved
            assert (float(bstats.time_saved_bottleneck[0])
                    == stats.time_saved_bottleneck)
            assert (float(bstats.time_saved_extra[0])
                    == stats.time_saved_extra)


def test_bmf_stats_time_saved_split():
    """`BMFStats.time_saved` = bottleneck-loop + optimize_all shares, each
    accounted separately so the ablation benchmark can attribute gains."""
    bw = np.full((6, 6), 1.0)
    np.fill_diagonal(bw, 0.0)
    bw[0, 1] = 2.0                    # bottleneck: direct 10s
    bw[0, 4] = bw[4, 1] = 5.0         # ... 0->4->1 takes 8s, still worst
    bw[2, 3] = 4.0                    # secondary: direct 5s ...
    bw[2, 5] = bw[5, 3] = 20.0        # ... 2->5->3 takes 2s (extra pass)
    rnd = Round(transfers=[
        Transfer(src=0, dst=1, job=0, terms=frozenset({0})),
        Transfer(src=2, dst=3, job=0, terms=frozenset({2})),
    ])
    _, plain = optimize_round(rnd, bw, [4, 5], 20.0)
    assert plain.time_saved_bottleneck > 0
    assert plain.time_saved_extra == 0.0
    assert plain.time_saved == plain.time_saved_bottleneck
    _, both = optimize_round(rnd, bw, [4, 5], 20.0, optimize_all=True)
    assert both.time_saved_bottleneck == plain.time_saved_bottleneck
    assert both.time_saved_extra > 0
    assert both.time_saved == pytest.approx(
        both.time_saved_bottleneck + both.time_saved_extra)


# ------------------------------------------------ scheduler oracle pinning
def _msrepair_reference(jobs, *, max_rounds=64):
    """The historical object walk: candidates recomputed after every pick."""
    from repro.core.msrepair import node_sets

    r_set, nr_set, rp_set = node_sets(jobs)

    def set_of(node):
        if node in rp_set:
            return "RP"
        if node in r_set:
            return "R"
        if node in nr_set:
            return "NR"
        return "IDLE"

    state = FragmentState(jobs)
    job_by_id = {j.job_id: j for j in jobs}
    rounds = []
    priority = (("R", "R"), ("R", "NR"), ("NR", "RP"), ("NR", "NR"),
                ("R", "RP"), ("NR", "R"))
    for _ in range(max_rounds):
        if state.all_done():
            break
        busy, rnd = set(), Round()

        def candidates_in(cls):
            cands = []
            for job_id, holders in state.holdings.items():
                if state.job_done(job_id):
                    continue
                req = job_by_id[job_id].requestor
                for src, terms in holders.items():
                    if src in busy or set_of(src) != cls[0] or src == req:
                        continue
                    for dst in list(holders.keys()) + [req]:
                        if dst == src or dst in busy or set_of(dst) != cls[1]:
                            continue
                        if dst != req and dst not in holders:
                            continue
                        load = sum(1 for h in state.holdings.values()
                                   if src in h)
                        cands.append((-load, job_id, src, dst,
                                      frozenset(terms)))
            cands.sort()
            return cands

        for cls in priority:
            while True:
                cands = candidates_in(cls)
                if not cands:
                    break
                _, job_id, src, dst, terms = cands[0]
                tr = Transfer(src=src, dst=dst, job=job_id, terms=terms)
                state.apply(tr)
                rnd.transfers.append(tr)
                busy.update((src, dst))
        rounds.append(rnd)
    return rounds


def _mask_terms(mask):
    out, m = [], int(mask)
    while m:
        b = m & -m
        out.append(b.bit_length() - 1)
        m ^= b
    return frozenset(out)


def _multi_jobs(seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 6))
    n = int(rng.integers(k + 2, k + 7))
    nf = int(rng.integers(2, min(4, n - k) + 1))
    failed = sorted(rng.choice(n, size=nf, replace=False).tolist())
    helpers = select_helpers_multi(n, k, failed)
    return [Job(job_id=i, failed_node=f, requestor=f, helpers=helpers[i])
            for i, f in enumerate(failed)]


@pytest.mark.parametrize("seed", range(25))
def test_msrepair_schedulers_match_reference_walk(seed):
    jobs = _multi_jobs(seed)
    want = _msrepair_reference(jobs)
    got_tuple = msrepair_schedule(jobs)
    got_batch = msrepair_schedule_batch([jobs])[0]
    assert got_tuple == got_batch
    assert len(got_tuple) == len(want)
    for rnd_t, rnd_w in zip(got_tuple, want):
        assert [(s, d, j, _mask_terms(m)) for s, d, j, m in rnd_t] == \
            [(t.src, t.dst, t.job, t.terms) for t in rnd_w.transfers]


def test_msrepair_batch_mixed_cases_and_fallback():
    batch = [_multi_jobs(s) for s in range(8)]
    batch.append([Job(job_id=0, failed_node=0, requestor=0,
                      helpers=(65, 66)),
                  Job(job_id=1, failed_node=1, requestor=1,
                      helpers=(66, 67))])  # ids >= 64: tuple fallback
    got = msrepair_schedule_batch(batch)
    for jobs, sched in zip(batch, got):
        assert sched == msrepair_schedule(jobs)


def test_random_schedule_preserves_rng_draw_sequence():
    """The filtered candidate list must match a per-pick recompute, so the
    within-round rng consumption (and thus the schedule) is unchanged,
    and the object facade must walk the identical schedule."""
    for seed in range(10):
        jobs = _multi_jobs(seed + 100)
        a = random_schedule(jobs, seed=seed)
        b = random_schedule(jobs, seed=seed)
        assert a == b
        plan = plan_for_scheme("random", jobs, random_seed=seed)
        got = [[(t.src, t.dst, t.job, t.terms) for t in rnd.transfers]
               for rnd in plan.rounds]
        want = [[(s, d, j, _mask_terms(m)) for s, d, j, m in rnd]
                for rnd in a]
        assert got == want


# paper Table II RS(7,4) double-failure fixture (same as test_msrepair)
_TABLE2_JOBS = [
    Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3, 4, 5)),
    Job(job_id=1, failed_node=1, requestor=1, helpers=(3, 4, 5, 6)),
]


def test_random_schedule_v2_versioned_expectation():
    """`RANDOM_SCHEDULE_VERSION` pins the schedule semantics: per-round
    rng counter-keyed on (seed, round) and sorted (job, src, dst)
    candidate enumeration — rounds are pure functions of
    (seed, round, holdings), which is what lets the random baseline
    batch like the other schemes (no shared cross-round rng stream).
    Changing either ingredient changes every random-baseline schedule:
    bump the version and refresh this expectation deliberately.
    """
    assert RANDOM_SCHEDULE_VERSION == 2
    assert random_schedule(_TABLE2_JOBS, seed=0) == [
        [(5, 6, 1, 32), (4, 3, 0, 16), (2, 0, 0, 4)],
        [(3, 4, 1, 8), (6, 1, 1, 96), (5, 0, 0, 32)],
        [(4, 1, 1, 24), (3, 0, 0, 24)],
    ]
    assert random_schedule(_TABLE2_JOBS, seed=7) == [
        [(6, 4, 1, 64), (5, 3, 0, 32), (2, 0, 0, 4)],
        [(5, 3, 1, 32), (4, 1, 1, 80)],
        [(3, 4, 0, 40)],
        [(3, 1, 1, 40), (4, 0, 0, 56)],
    ]


def test_random_schedule_rounds_are_counter_keyed():
    """Round r's draws must not depend on how many draws earlier rounds
    consumed: replaying the same holdings state under a fresh scheduler
    reproduces the same rounds (the lockstep-batching property)."""
    jobs = _multi_jobs(42)
    full = random_schedule(jobs, seed=3)
    # re-run: identical prefix round by round (pure in (seed, round))
    assert random_schedule(jobs, seed=3) == full
    # different seeds diverge (the case key feeds the counter)
    assert random_schedule(jobs, seed=4) != full


# ---------------------------------------------------- lowering + validation
def test_plan_arrays_for_scheme_matches_object_planners():
    sjob = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2, 3))]
    mjobs = _multi_jobs(3)
    for scheme, jobs in [("traditional", sjob), ("ppr", sjob),
                         ("bmf", sjob), ("mppr", mjobs),
                         ("random", mjobs), ("msrepair", mjobs)]:
        pa = plan_arrays_for_scheme(scheme, list(jobs), random_seed=7)
        assert decompile(pa) == plan_for_scheme(scheme, list(jobs),
                                                random_seed=7)


def test_lower_schedules_batch_views_and_unsupported():
    items = [schedule_for_scheme("msrepair", _multi_jobs(s))
             for s in range(5)]
    big = [Job(job_id=0, failed_node=0, requestor=0, helpers=(70, 71, 72))]
    items.append(schedule_for_scheme("ppr", big))
    pas = lower_schedules_batch(items)
    assert pas[-1] is None                      # term ids >= 64: fallback
    for (jobs, sched, meta), pa in zip(items[:-1], pas[:-1]):
        assert pa is not None
        validate_plan_arrays(pa)
        assert decompile(pa).meta == meta
    with pytest.raises(UnsupportedPlanError):
        plan_arrays_for_scheme("ppr", big)


def test_lower_schedules_batch_rejects_invalid():
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    # node 1 sends twice in one round
    bad = [[(1, 0, 0, 1 << 1), (1, 3, 0, 1 << 2)]]
    with pytest.raises(ValueError):
        lower_schedules_batch([(jobs, bad, {"scheme": "x"})])


# ----------------------------------------------------- PlanArrays mutation
def test_splice_path_widens_and_validates():
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    sched = [[(1, 0, 0, 1 << 1)], [(2, 0, 0, 1 << 2)]]
    pa = lower_schedules_batch([(jobs, sched, {"scheme": "x"})])[0]
    assert pa.t_path.shape[1] == 2
    splice_path(pa, 0, (1, 5, 6, 0))            # widens the path axis
    assert pa.t_path.shape[1] == 4
    assert pa.num_nodes >= 7
    validate_plan_arrays(pa)                    # relayed plan still valid
    plan = decompile(pa)
    assert plan.rounds[0].transfers[0].path == (1, 5, 6, 0)
    with pytest.raises(ValueError):
        splice_path(pa, 0, (1, 5))              # endpoint mismatch
    with pytest.raises(ValueError):
        splice_path(pa, 0, (1, 5, 5, 0))        # cyclic
    # a relay colliding with the round's receiver must fail full validation
    splice_path(pa, 0, (1, 0))
    splice_path(pa, 1, (2, 0))
    splice_path(pa, 0, (1, 2, 0))               # relay 2 sends in round 2?
    validate_plan_arrays(pa)                    # different rounds: fine
    splice_path(pa, 1, (2, 1, 0))               # 1 already sent in round 1?
    validate_plan_arrays(pa)                    # different rounds: fine


def test_batched_search_exact_tie_prefers_dfs_preorder_route():
    """Regression: with exact-tie hop sums (power-of-two bandwidths) the
    depth-3 block must still be priced — the DFS pre-order prefers the
    deeper route on a tie, and skipping d3 on `4*minw == best2` diverged
    from the scalar search."""
    n = 6
    bw = np.zeros((n, n))
    for u, v in [(0, 2), (2, 3), (3, 4), (4, 1)]:
        bw[u, v] = 4.0                    # four 0.25s hops = 1.0s
    bw[0, 5] = bw[5, 1] = 2.0             # two 0.5s hops = 1.0s
    from repro.core.bmf import find_min_time_path

    want = find_min_time_path(0, 1, [2, 3, 4, 5], bw, 1.0, np.inf)
    got_path, got_t, _ = _search_one(0, 1, [2, 3, 4, 5], bw, 1.0, np.inf)
    assert (got_path, got_t) == want
    assert got_path == (0, 2, 3, 4, 1)    # the deeper pre-order winner


def test_bmf_replan_excludes_all_failed_nodes_in_multi_failure_scenarios():
    """Regression: for bmf/bmf_static the compiled plan carries only the
    first job, but the batched replanner's idle pool must still exclude
    every failed node of the scenario (as `simulator._idle_pool` does) —
    otherwise the vectorized engine relays repair traffic through a
    failed node."""
    cluster = 10
    base = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=0)
    base[:, 1] = base[1, :] = 100.0       # failed node 1: tempting relay
    np.fill_diagonal(base, 0.0)
    bwp = BandwidthProcess(base=base, change_interval=None)
    sc = Scenario(num_nodes=cluster, code=RSCode(7, 4), failed=(0, 1),
                  bw=bwp, ingress=IngressModel(seed=0), chunk_mb=16.0,
                  helpers=((2, 3, 4, 5), (3, 4, 5, 6)))
    for scheme in ("bmf", "bmf_static"):
        ref = run_scheme(sc, scheme)
        got = run_scheme_vectorized([sc], scheme)[0]
        assert got.relay_hops == ref.relay_hops, scheme
        assert got.total_time == pytest.approx(ref.total_time, rel=RTOL)
        assert got.plan == ref.plan, scheme
        for rnd in got.plan.rounds:       # and 1 truly never relays
            for tr in rnd.transfers:
                assert 1 not in tr.relays


# --------------------------------- end-to-end parity across regimes/schemes
def _scenario(regime, n, k, failed, seed, cluster=10, chunk=8.0):
    base = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    modes = {
        "jitter": dict(mode="jitter", jitter=0.5),
        "redraw": dict(mode="redraw"),
        "markov": dict(mode="markov"),
    }
    bwp = BandwidthProcess(base=base, change_interval=2.0, seed=seed,
                           **modes[regime])
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk)


@pytest.mark.parametrize("regime", ["jitter", "redraw", "markov"])
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_batched_planner_parity_all_schemes_all_regimes(regime, scheme):
    """The batched planner layer must pin plans — round counts, relay
    hops, repair times at 1e-6 rtol, and the executed plans themselves —
    to the object planners, for every scheme under every volatility
    regime (the acceptance suite for the array-native planner layer)."""
    failed = (0, 1) if scheme in ("mppr", "random", "msrepair") else (0,)
    seeds = list(range(4))
    scs = [_scenario(regime, 7, 4, failed, seed=s) for s in seeds]
    ref = [run_scheme(sc, scheme, random_seed=s)
           for s, sc in zip(seeds, scs)]
    got = run_scheme_vectorized(scs, scheme, seeds=seeds)
    for s, (a, b) in enumerate(zip(ref, got)):
        label = f"{scheme}/{regime}/seed={s}"
        assert b.num_rounds == a.num_rounds, label
        assert b.relay_hops == a.relay_hops, label
        assert b.total_time == pytest.approx(a.total_time, rel=RTOL), label
        for x, y in zip(a.round_times, b.round_times):
            assert y == pytest.approx(x, rel=RTOL, abs=1e-9), label
        assert b.log == a.log, label
        assert b.plan == a.plan, label
