"""Training substrate: learning happens, microbatching is consistent,
gradient compression's error feedback behaves."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticStream
from repro.train.optimizer import (AdamWConfig, compress_grads,
                                   init_ef_state, lr_at)
from repro.train.train_step import TrainConfig, init_state, make_train_step

CFG = get_arch("smollm_360m").reduced()
SHAPE = ShapeConfig("t", "train", 32, 8)


def _run(tcfg, steps=25, seed=0):
    state = init_state(jax.random.PRNGKey(seed), CFG, tcfg)
    step = jax.jit(make_train_step(CFG, tcfg))
    stream = SyntheticStream(CFG, SHAPE)
    losses = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases():
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=1e-2, warmup_steps=5),
                       attn_chunk=16)
    losses, _ = _run(tcfg, steps=30)
    assert losses[-1] < losses[0] - 0.3


def test_microbatch_equivalence():
    """mb=1 and mb=2 average the same gradients -> nearly equal loss path."""
    t1 = TrainConfig(adamw=AdamWConfig(peak_lr=5e-3, warmup_steps=5),
                     microbatches=1, attn_chunk=16)
    t2 = TrainConfig(adamw=AdamWConfig(peak_lr=5e-3, warmup_steps=5),
                     microbatches=2, attn_chunk=16)
    l1, s1 = _run(t1, steps=8)
    l2, s2 = _run(t2, steps=8)
    assert abs(l1[-1] - l2[-1]) < 0.05
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32)))) < 0.05


def test_compressed_grads_still_learn():
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=1e-2, warmup_steps=5),
                       attn_chunk=16, compress_grads=True)
    losses, _ = _run(tcfg, steps=30)
    assert losses[-1] < losses[0] - 0.25


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32)}
    ef = init_ef_state(g)
    gq, ef2 = compress_grads(g, ef)
    # dequantized + residual == original (exact identity of EF)
    recon = gq["w"].astype(jnp.float32) + ef2["w"]
    assert float(jnp.max(jnp.abs(recon - g["w"]))) < 1e-6
    # int8 grid: quantization error bounded by scale
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(gq["w"] - g["w"]))) <= scale + 1e-7


def test_lr_schedule():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_at(cfg, jnp.asarray(100))) < 2e-4


def test_grad_clipping_bounds_update():
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=1e-2, warmup_steps=1,
                                         grad_clip=0.1), attn_chunk=16)
    _, state = _run(tcfg, steps=3)
    assert int(state["step"]) == 3
