"""Decode == teacher-forced forward, per model family (KV-cache / state
correctness), plus chunk-size invariance for the recurrent families."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import model as M
from repro.models import rwkv6, transformer, whisper, zamba2

TOL = 0.06   # bf16 params + f32 accumulation reorder


def _tokens(cfg, b=2, t=12, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0,
                              cfg.vocab_size)


@pytest.mark.parametrize("arch_id", [
    "qwen2_15b", "grok1_314b", "gemma3_4b", "gemma_2b", "smollm_360m",
    "moonlight_16b_a3b", "qwen2vl_2b",
])
def test_transformer_decode_matches_forward(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg)
    b, t = tokens.shape
    pos3 = (jnp.broadcast_to(jnp.arange(t)[None, None], (3, b, t))
            .astype(jnp.int32) if cfg.mrope else None)
    logits, _ = transformer.forward(params, cfg, tokens, pos3=pos3, chunk=8)
    _, cache = transformer.prefill(
        params, cfg, tokens[:, : t - 2], max_len=t + 2, chunk=8,
        pos3=pos3[:, :, : t - 2] if pos3 is not None else None)
    for i in (t - 2, t - 1):
        step_pos3 = (jnp.full((3, b, 1), i, jnp.int32) if cfg.mrope else None)
        lg, cache = transformer.decode_step(params, cfg, tokens[:, i], cache,
                                            chunk=8, pos3=step_pos3)
        err = float(jnp.max(jnp.abs(lg - logits[:, i])))
        assert err < TOL, (arch_id, i, err)


def test_rwkv6_chunk_invariance_and_decode():
    cfg = get_arch("rwkv6_16b").reduced()
    params = rwkv6.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg)
    ref, _ = rwkv6.forward(params, cfg, tokens, chunk=4)
    for chunk in (1, 3, 8, 12):
        out, _ = rwkv6.forward(params, cfg, tokens, chunk=chunk)
        assert float(jnp.max(jnp.abs(out - ref))) < TOL, chunk
    state = rwkv6.init_state(cfg, 2)
    outs = []
    for i in range(tokens.shape[1]):
        lg, state = rwkv6.decode_step(params, cfg, tokens[:, i], state)
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - ref)))
    assert err < TOL, err


def test_zamba2_decode_matches_forward():
    cfg = get_arch("zamba2_7b").reduced()
    params = zamba2.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg)
    logits, _ = zamba2.forward(params, cfg, tokens, ssm_chunk=4, attn_chunk=8)
    _, cache = zamba2.prefill(params, cfg, tokens[:, :-1],
                              max_len=tokens.shape[1] + 1,
                              ssm_chunk=4, attn_chunk=8)
    lg, _ = zamba2.decode_step(params, cfg, tokens[:, -1], cache)
    err = float(jnp.max(jnp.abs(lg - logits[:, -1])))
    assert err < TOL, err


def test_whisper_incremental_decode():
    cfg = get_arch("whisper_medium").reduced()
    params = whisper.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    tokens = _tokens(cfg, t=8)
    logits, _ = whisper.forward(params, cfg, frames, tokens, chunk=8)
    memory = whisper.encode(params, cfg, frames, chunk=8, remat=False)
    xk, xv = whisper.cross_kv(params, cfg, memory)
    cache = whisper.init_self_cache(cfg, 2, 12)
    outs = []
    for i in range(8):
        lg, cache = whisper.decode(params, cfg, tokens[:, i:i + 1],
                                   xk=xk, xv=xv, self_cache=cache, chunk=8,
                                   remat=False)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - logits)))
    assert err < TOL, err


def test_generate_runs_all_families():
    from repro.serve.serve_step import generate
    for arch_id in ("qwen2_15b", "rwkv6_16b", "zamba2_7b", "whisper_medium"):
        cfg = get_arch(arch_id).reduced()
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": _tokens(cfg, t=8)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        out = generate(params, cfg, batch, steps=4, chunk=8)
        assert out.shape == (2, 4), arch_id


def test_int8_kv_cache_decode():
    """int8 KV cache (production decode memory option): logits stay within
    quantization tolerance of the bf16-cache path, cache dtypes correct."""
    cfg = get_arch("qwen2_15b").reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg, t=14)
    logits, _ = transformer.forward(params, cfg, tokens, chunk=8)
    _, cache = transformer.prefill(params, cfg, tokens[:, :13], max_len=16,
                                   chunk=8, kv_dtype="int8")
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.float16
    lg, cache = transformer.decode_step(params, cfg, tokens[:, 13], cache,
                                        chunk=8)
    err = float(jnp.max(jnp.abs(lg - logits[:, 13])))
    assert err < 0.6, err          # int8 quantization noise bound
    # multi-step decode keeps working (scales update in the cache)
    lg2, cache = transformer.decode_step(params, cfg, jnp.argmax(lg, -1),
                                         cache, chunk=8)
    assert jnp.isfinite(lg2).all()
