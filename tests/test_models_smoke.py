"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import SyntheticStream
from repro.models import model as M
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step

SHAPE = ShapeConfig("smoke", "train", 16, 2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=1e-3, warmup_steps=2),
                       microbatches=1, attn_chunk=8)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    stream = SyntheticStream(cfg, SHAPE)
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}

    # forward: logits shape + finite
    loss0 = M.train_loss(state["params"], cfg, batch, chunk=8)
    assert jnp.isfinite(loss0), arch_id

    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch_id
    assert int(state["step"]) == 1
    state, metrics = step(state, batch)   # step 2: warmup lr > 0
    assert jnp.isfinite(metrics["loss"]), arch_id
    # params actually changed
    p0 = jax.tree.leaves(init_state(jax.random.PRNGKey(0), cfg, tcfg)["params"])
    p1 = jax.tree.leaves(state["params"])
    changed = any(not jnp.array_equal(a, b) for a, b in zip(p0, p1))
    assert changed, arch_id


@pytest.mark.parametrize("arch_id", ["grok1_314b", "moonlight_16b_a3b"])
def test_moe_aux_loss_finite(arch_id):
    cfg = get_arch(arch_id).reduced()
    from repro.models import transformer as T
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    logits, aux = T.forward(params, cfg, tokens, chunk=8)
    assert jnp.isfinite(aux) and aux >= 0.0


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    spec = {
        "grok1_314b": (64, 6144, 48, 8, 32768, 131072),
        "moonlight_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "smollm_360m": (32, 960, 15, 5, 2560, 49152),
        "qwen2_15b": (28, 1536, 12, 2, 8960, 151936),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "rwkv6_16b": (24, 2048, 32, 32, 7168, 65536),
        "qwen2vl_2b": (28, 1536, 12, 2, 8960, 151936),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    }
    for arch_id, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_arch(arch_id)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (l, d, h, kv, ff, v), arch_id
    assert get_arch("grok1_314b").moe.num_experts == 8
    assert get_arch("grok1_314b").moe.top_k == 2
    assert get_arch("moonlight_16b_a3b").moe.num_experts == 64
    assert get_arch("moonlight_16b_a3b").moe.top_k == 6
    assert get_arch("zamba2_7b").ssm_state == 64
    assert get_arch("gemma3_4b").global_every == 6      # 5:1 local:global
    assert get_arch("gemma_2b").hd == 256


def test_applicable_shapes():
    from repro.configs import applicable_shapes
    assert "long_500k" in applicable_shapes(get_arch("rwkv6_16b"))
    assert "long_500k" in applicable_shapes(get_arch("zamba2_7b"))
    assert "long_500k" in applicable_shapes(get_arch("gemma3_4b"))
    for a in ("grok1_314b", "qwen2_15b", "whisper_medium", "gemma_2b"):
        assert "long_500k" not in applicable_shapes(get_arch(a))
        assert len(applicable_shapes(get_arch(a))) == 3
