"""Plan-array IR: exact compile/decompile round-trips, array-vs-object
validation equivalence, and `validate_plan` edge cases."""
import dataclasses

import numpy as np
import pytest

from repro.core import bmf, topology
from repro.core.engine.arrays import (UnsupportedPlanError, compile_plan,
                                      decompile, validate_plan_arrays)
from repro.core.msrepair import (plan_mppr, plan_msrepair, plan_random,
                                 select_helpers_multi)
from repro.core.plan import Job, RepairPlan, Round, Transfer, validate_plan
from repro.core.ppr import plan_ppr, plan_traditional


def _single_job(n, k, failed=0):
    helpers = tuple(x for x in range(n) if x != failed)[:k]
    return Job(job_id=0, failed_node=failed, requestor=failed, helpers=helpers)


def _multi_jobs(n, k, failed):
    helper_sets = select_helpers_multi(n, k, list(failed))
    return [Job(job_id=i, failed_node=f, requestor=f, helpers=helper_sets[i])
            for i, f in enumerate(failed)]


def _all_planner_outputs():
    """One plan per planner across a few shapes (incl. BMF-relayed paths)."""
    plans = []
    for n, k in [(4, 2), (6, 3), (7, 4), (9, 6), (12, 8)]:
        job = _single_job(n, k)
        plans.append(plan_ppr(job))
        plans.append(plan_traditional(job))
    for n, k, failed in [(7, 4, (0, 1)), (9, 6, (0, 1, 2)), (6, 3, (2, 5))]:
        jobs = _multi_jobs(n, k, failed)
        plans.append(plan_mppr(jobs))
        plans.append(plan_msrepair(jobs))
        for seed in (0, 3):
            plans.append(plan_random(jobs, seed=seed))
    # BMF-optimized rounds carry store-and-forward relay paths (len > 2)
    for seed in range(4):
        job = _single_job(7, 4)
        plan = plan_ppr(job)
        bw = topology.heterogeneous_matrix(12, low=1, high=30, seed=seed)
        idle = list(range(7, 12))
        rounds = [
            bmf.optimize_round(r, bw, [x for x in idle], 16.0)[0]
            for r in plan.rounds
        ]
        plans.append(RepairPlan(jobs=plan.jobs, rounds=rounds,
                                meta={"scheme": "bmf", "seed": seed}))
    return plans


# ----------------------------------------------------------- round-tripping
def test_compile_decompile_roundtrips_every_planner_exactly():
    plans = _all_planner_outputs()
    assert any(len(t.path) > 2 for p in plans for t in p.all_transfers()), \
        "fixture must include relayed paths"
    for plan in plans:
        pa = compile_plan(plan)
        back = decompile(pa)
        assert back == plan           # dataclass equality: jobs, rounds, meta
        # and the structural metadata is consistent
        assert pa.num_rounds == plan.num_rounds
        assert pa.num_transfers == len(plan.all_transfers())
        assert pa.num_jobs == len(plan.jobs)


def test_round_hops_matches_paths():
    plan = _all_planner_outputs()[-1]
    pa = compile_plan(plan)
    for r, rnd in enumerate(plan.rounds):
        hop_u, hop_v, n_hops = pa.round_hops(r)
        for i, tr in enumerate(rnd.transfers):
            nh = int(n_hops[i])
            assert nh == len(tr.path) - 1
            hops = list(zip(tr.path[:-1], tr.path[1:]))
            assert [(int(u), int(v)) for u, v in
                    zip(hop_u[i, :nh], hop_v[i, :nh])] == hops


def test_compile_rejects_unmappable_node_ids():
    job = Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2, 64))
    plan = RepairPlan(jobs=[job], rounds=[])
    with pytest.raises(UnsupportedPlanError):
        compile_plan(plan)


# --------------------------------------------- array/object path equivalence
def test_valid_plans_pass_both_paths():
    for plan in _all_planner_outputs():
        max_recv = (len(plan.jobs[0].helpers)
                    if plan.meta.get("scheme") == "traditional" else 1)
        validate_plan(plan, max_recv_per_round=max_recv, fast=False)
        validate_plan(plan, max_recv_per_round=max_recv, fast=True)
        validate_plan_arrays(compile_plan(plan), max_recv_per_round=max_recv)


def _expect_both_paths_reject(plan, match, *, max_recv_per_round=1):
    with pytest.raises(ValueError, match=match):
        validate_plan(plan, max_recv_per_round=max_recv_per_round, fast=False)
    with pytest.raises(ValueError, match=match):
        validate_plan_arrays(compile_plan(plan),
                             max_recv_per_round=max_recv_per_round)


def _two_jobs():
    return [
        Job(job_id=0, failed_node=0, requestor=0, helpers=(2, 3)),
        Job(job_id=1, failed_node=1, requestor=1, helpers=(4, 5)),
    ]


def test_relay_reused_across_jobs_in_one_round_rejected():
    jobs = _two_jobs()
    rnd = Round(transfers=[
        Transfer(src=2, dst=3, job=0, terms=frozenset({2}), path=(2, 6, 3)),
        Transfer(src=4, dst=5, job=1, terms=frozenset({4}), path=(4, 6, 5)),
    ])
    _expect_both_paths_reject(
        RepairPlan(jobs=jobs, rounds=[rnd]), match="relay node 6 used 2")


def test_stale_fragment_replay_rejected():
    """A node re-sending a fragment it already forwarded must be caught."""
    job = Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2, 3))
    rounds = [
        Round(transfers=[Transfer(src=1, dst=2, job=0, terms=frozenset({1}))]),
        Round(transfers=[Transfer(src=1, dst=2, job=0, terms=frozenset({1}))]),
    ]
    _expect_both_paths_reject(
        RepairPlan(jobs=[job], rounds=rounds), match="not matching src")


def test_duplicate_term_arrival_rejected():
    """The XOR-fold duplicate guard (unreachable from canonical initial
    holdings, where every term exists exactly once — `FragmentState` is
    the layer that enforces it for injected/replayed state)."""
    from repro.core.plan import FragmentState

    job = Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))
    state = FragmentState([job])
    state.holdings[0][3] = {1}           # synthetic duplicate of term 1
    state.apply(Transfer(src=1, dst=0, job=0, terms=frozenset({1})))
    with pytest.raises(ValueError, match="duplicate terms"):
        state.apply(Transfer(src=3, dst=0, job=0, terms=frozenset({1})))


def test_disjoint_fan_in_accepted_redelivery_rejected():
    # two sources delivering disjoint term sets to one receiver is the
    # legal traditional-repair shape (with fan-in relaxed) ...
    job = Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))
    ok = RepairPlan(jobs=[job], rounds=[Round(transfers=[
        Transfer(src=1, dst=0, job=0, terms=frozenset({1})),
        Transfer(src=2, dst=0, job=0, terms=frozenset({2})),
    ])])
    validate_plan(ok, max_recv_per_round=2, fast=False)
    validate_plan_arrays(compile_plan(ok), max_recv_per_round=2)
    # ... but re-delivering an already-forwarded aggregate is not
    dup = RepairPlan(jobs=[job], rounds=[
        Round(transfers=[Transfer(src=1, dst=2, job=0, terms=frozenset({1}))]),
        Round(transfers=[Transfer(src=2, dst=0, job=0, terms=frozenset({1, 2}))]),
        Round(transfers=[Transfer(src=2, dst=0, job=0, terms=frozenset({1, 2}))]),
    ])
    _expect_both_paths_reject(dup, match="not matching src")


def test_max_recv_per_round_relaxation():
    """Traditional star repair is only valid once fan-in is relaxed."""
    plan = plan_traditional(_single_job(6, 3))
    k = len(plan.jobs[0].helpers)
    for fast in (False, True):
        with pytest.raises(ValueError, match="receives"):
            validate_plan(plan, max_recv_per_round=1, fast=fast)
        validate_plan(plan, max_recv_per_round=k, fast=fast)
    with pytest.raises(ValueError, match="receives"):
        validate_plan_arrays(compile_plan(plan), max_recv_per_round=k - 1)
    validate_plan_arrays(compile_plan(plan), max_recv_per_round=k)


def test_incomplete_plan_rejected():
    job = Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))
    plan = RepairPlan(jobs=[job], rounds=[
        Round(transfers=[Transfer(src=1, dst=0, job=0, terms=frozenset({1}))]),
    ])
    _expect_both_paths_reject(plan, match="does not complete")


def test_role_conflicts_rejected_by_both_paths():
    jobs = _two_jobs()
    send_and_recv = RepairPlan(jobs=jobs, rounds=[Round(transfers=[
        Transfer(src=2, dst=3, job=0, terms=frozenset({2})),
        Transfer(src=4, dst=2, job=1, terms=frozenset({4}), path=(4, 2)),
    ])])
    _expect_both_paths_reject(send_and_recv, match="sends and receives")
    relay_and_send = RepairPlan(jobs=jobs, rounds=[Round(transfers=[
        Transfer(src=2, dst=3, job=0, terms=frozenset({2})),
        Transfer(src=4, dst=5, job=1, terms=frozenset({4}), path=(4, 2, 5)),
    ])])
    _expect_both_paths_reject(relay_and_send, match="relay")


def test_transfer_post_init_rejects_cycles():
    with pytest.raises(AssertionError, match="cyclic"):
        Transfer(src=1, dst=1, job=0, terms=frozenset({1}), path=(1, 2, 1))
    with pytest.raises(AssertionError, match="cyclic"):
        Transfer(src=1, dst=3, job=0, terms=frozenset({1}), path=(1, 2, 2, 3))
    # and endpoints must match the declared path
    with pytest.raises(AssertionError):
        Transfer(src=1, dst=3, job=0, terms=frozenset({1}), path=(2, 3))


def test_meta_and_helper_order_survive_roundtrip():
    jobs = [Job(job_id=5, failed_node=1, requestor=1, helpers=(4, 2, 6))]
    plan = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[
            Transfer(src=4, dst=2, job=5, terms=frozenset({4})),
        ]),
        Round(transfers=[
            Transfer(src=2, dst=6, job=5, terms=frozenset({4, 2})),
        ]),
        Round(transfers=[
            Transfer(src=6, dst=1, job=5, terms=frozenset({4, 2, 6})),
        ]),
    ], meta={"scheme": "custom", "note": [1, 2]})
    back = decompile(compile_plan(plan))
    assert back == plan
    assert back.jobs[0].helpers == (4, 2, 6)      # order, not a set
    assert back.meta == {"scheme": "custom", "note": [1, 2]}
