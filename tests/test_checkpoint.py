"""EC checkpoint: save/load roundtrip, domain-loss repair, async commit."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ECCheckpointConfig, ECCheckpointer
from repro.configs import get_arch
from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state


@pytest.fixture
def ckpt_env():
    d = tempfile.mkdtemp()
    _, bwm = topology.tpu_pod_dcn_matrix(8, 1)
    ck = ECCheckpointer(
        ECCheckpointConfig(directory=d, n=6, k=4, chunk_bytes=1 << 14,
                           num_domains=8),
        bw=BandwidthProcess(base=bwm, change_interval=2.0, mode="markov"),
        ingress=IngressModel(),
    )
    cfg = get_arch("smollm_360m").reduced()
    tcfg = TrainConfig(adamw=AdamWConfig())
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    yield ck, state, d
    shutil.rmtree(d, ignore_errors=True)


def _assert_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_no_loss(ckpt_env):
    ck, state, d = ckpt_env
    ck.save(7, state, wait=True)
    restored, report = ck.load(state)
    _assert_equal(state, restored)
    assert report.blocks_repaired == 0
    assert ck.latest_step() == 7


@pytest.mark.parametrize("lost", [(3,), (1, 5)])
def test_repair_lost_domains(ckpt_env, lost):
    ck, state, d = ckpt_env
    ck.save(1, state, wait=True)
    restored, report = ck.load(state, lost_domains=lost)
    _assert_equal(state, restored)
    assert report.lost_domains == tuple(sorted(lost))
    assert report.blocks_repaired > 0
    assert report.sim is not None and report.sim.total_time > 0


def test_too_many_losses_raises(ckpt_env):
    ck, state, d = ckpt_env
    ck.save(1, state, wait=True)
    with pytest.raises(RuntimeError):
        ck.load(state, lost_domains=(0, 1, 2))    # > n-k = 2 per stripe


def test_corrupt_domain_detected(ckpt_env):
    ck, state, d = ckpt_env
    ck.save(1, state, wait=True)
    # flip bytes in one domain file -> checksum treats it as lost
    path = os.path.join(ck._step_dir(1), "domain_2.bin")
    buf = bytearray(open(path, "rb").read())
    buf[100] ^= 0xFF
    open(path, "wb").write(bytes(buf))
    restored, report = ck.load(state)
    _assert_equal(state, restored)
    assert 2 in report.lost_domains


def test_async_save_then_load(ckpt_env):
    ck, state, d = ckpt_env
    ck.save(3, state)           # async
    ck.wait()
    restored, _ = ck.load(state)
    _assert_equal(state, restored)


def test_latest_step_picks_max(ckpt_env):
    ck, state, d = ckpt_env
    ck.save(1, state, wait=True)
    ck.save(9, state, wait=True)
    assert ck.latest_step() == 9
