# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (and subprocess-based mesh
# tests) force a host-platform device count.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
