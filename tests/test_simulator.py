"""Event-driven simulator: determinism, churn integration, scheme laws."""
import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.simulator import (RepairSimulator, Scenario, execute_round)
from repro.core.plan import Transfer
from repro.ec.rs import RSCode


def _scenario(n=6, k=3, failed=(0,), seed=0, interval=2.0, chunk=16.0,
              cluster=None, mode="markov"):
    cluster = cluster or n
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=interval, seed=seed,
                           mode=mode)
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk)


def test_deterministic():
    sc = _scenario()
    a = RepairSimulator(sc).run("bmf")
    b = RepairSimulator(sc).run("bmf")
    assert a.total_time == b.total_time
    assert a.round_times == b.round_times


def test_single_transfer_static_time_analytic():
    m = topology.uniform_matrix(3, 8.0)
    bwp = BandwidthProcess(base=m, change_interval=None)
    t = execute_round([Transfer(src=1, dst=0, job=0, terms=frozenset({1}))],
                      0.0, bwp, IngressModel(), 16.0)
    assert abs(t - 2.0) < 1e-6            # 16 MB / 8 MBps


def test_churn_integration_analytic():
    """Piecewise bandwidth 4 then 16 MBps, epoch 2 s, chunk 16 MB:
    8 MB in the first epoch, remaining 8 MB at 16 MBps -> 2.5 s."""
    base = topology.uniform_matrix(3, 4.0)

    class TwoEpoch(BandwidthProcess):
        def matrix_at(self, t):
            m = self.base.copy()
            if self.epoch_of(t) >= 1:
                m = m * 4.0
            np.fill_diagonal(m, 0.0)
            return m

    bwp = TwoEpoch(base=base, change_interval=2.0, jitter=0.0)
    t = execute_round([Transfer(src=1, dst=0, job=0, terms=frozenset({1}))],
                      0.0, bwp, IngressModel(), 16.0)
    assert abs(t - 2.5) < 1e-6


def test_all_zero_bandwidth_epoch_raises_not_hangs():
    """Regression: with every rate zero and no epoch flip ahead, dt used
    to stay inf (`max(inf, eps)`), poisoning `left` with NaN via
    `0 * inf`. The engine must clamp to the epsilon step and fail the
    convergence guard with a clean error instead."""
    base = np.zeros((3, 3))
    bwp = BandwidthProcess(base=base, change_interval=None, min_bw=0.0)
    assert bwp.matrix_at(0.0).max() == 0.0
    with pytest.raises(RuntimeError, match="failed to converge"):
        execute_round([Transfer(src=1, dst=0, job=0, terms=frozenset({1}))],
                      0.0, bwp, IngressModel(), 16.0)


def test_zero_bandwidth_epoch_then_recovery():
    """A dead epoch (all links zero) must stall cleanly until the next
    epoch flip, then finish: 2 s dead + 16 MB / 8 MBps = 4 s total."""
    base = topology.uniform_matrix(3, 8.0)

    class DeadFirstEpoch(BandwidthProcess):
        def matrix_at(self, t):
            if self.epoch_of(t) < 1:
                return np.zeros_like(self.base)
            return self.base

    bwp = DeadFirstEpoch(base=base, change_interval=2.0, jitter=0.0)
    t = execute_round([Transfer(src=1, dst=0, job=0, terms=frozenset({1}))],
                      0.0, bwp, IngressModel(), 16.0)
    assert abs(t - 4.0) < 1e-6


def test_relay_store_and_forward_sums_hops():
    m = topology.uniform_matrix(4, 8.0)
    bwp = BandwidthProcess(base=m, change_interval=None)
    tr = Transfer(src=1, dst=0, job=0, terms=frozenset({1}), path=(1, 2, 0))
    t = execute_round([tr], 0.0, bwp, IngressModel(), 16.0)
    assert abs(t - 4.0) < 1e-6            # 2 + 2 s (paper's sum-of-hops)


def test_static_bmf_never_worse_than_ppr():
    for seed in range(15):
        sc = _scenario(seed=seed, interval=None)
        sim = RepairSimulator(sc)
        assert (sim.run("bmf").total_time
                <= sim.run("ppr").total_time + 1e-9)


def test_all_schemes_complete_and_are_positive():
    sc = _scenario(n=7, k=4, cluster=10)
    sim = RepairSimulator(sc)
    for scheme in ("traditional", "ppr", "bmf", "ppt"):
        r = sim.run(scheme)
        assert r.total_time > 0 and np.isfinite(r.total_time)
    sc2 = _scenario(n=7, k=4, failed=(0, 1), cluster=10)
    sim2 = RepairSimulator(sc2)
    for scheme in ("mppr", "random", "msrepair"):
        r = sim2.run(scheme)
        assert r.total_time > 0 and np.isfinite(r.total_time)


def test_planning_time_fraction_small():
    """Paper Fig. 8: algorithm overhead ~3% of repair time."""
    sc = _scenario(n=7, k=4, cluster=14, chunk=32.0)
    r = RepairSimulator(sc).run("bmf")
    assert r.planning_time < 0.25 * r.total_time


def test_msrepair_beats_mppr_on_average():
    gains = []
    for seed in range(15):
        sc = _scenario(n=7, k=4, failed=(0, 1), seed=seed, cluster=10)
        sim = RepairSimulator(sc)
        gains.append(sim.run("mppr").total_time
                     - sim.run("msrepair").total_time)
    assert np.mean(gains) > 0


def test_bmf_beats_ppr_on_average_under_churn():
    gains = []
    for seed in range(15):
        sc = _scenario(seed=seed, cluster=10)
        sim = RepairSimulator(sc)
        gains.append(sim.run("ppr").total_time - sim.run("bmf").total_time)
    assert np.mean(gains) > 0
