"""Round-structure planners: PPR, traditional, m-PPR, random, MSRepair."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.msrepair import (
    node_sets, plan_mppr, plan_msrepair, plan_random, select_helpers_multi)
from repro.core.plan import Job, validate_plan
from repro.core.ppr import plan_ppr, plan_traditional


def _job(n, k, failed=0):
    helpers = tuple(x for x in range(n) if x != failed)[:k]
    return Job(job_id=0, failed_node=failed, requestor=failed, helpers=helpers)


@pytest.mark.parametrize("n,k", [(4, 2), (6, 3), (7, 4), (6, 4), (4, 3),
                                 (9, 6), (12, 8)])
def test_ppr_round_count(n, k):
    plan = plan_ppr(_job(n, k))
    assert plan.num_rounds == math.ceil(math.log2(k + 1))
    validate_plan(plan)


def test_ppr_matches_paper_rs63():
    """Paper Fig. 4: ts1: D2->D1, P1->D3; ts2: D3->D1 (0-indexed 1->0, 3->2,
    then 2->0)."""
    plan = plan_ppr(_job(6, 3))
    r1 = {(t.src, t.dst) for t in plan.rounds[0].transfers}
    r2 = {(t.src, t.dst) for t in plan.rounds[1].transfers}
    assert r1 == {(1, 0), (3, 2)}
    assert r2 == {(2, 0)}


def test_traditional_star():
    plan = plan_traditional(_job(6, 3))
    assert plan.num_rounds == 1
    assert len(plan.rounds[0].transfers) == 3
    validate_plan(plan, max_recv_per_round=3)


@st.composite
def multi_scenario(draw):
    k = draw(st.integers(2, 5))
    n = draw(st.integers(k + 2, min(k + 5, 10)))
    nf = draw(st.integers(2, min(3, n - k)))
    return n, k, nf


def _jobs(n, k, nf):
    failed = list(range(nf))
    helper_sets = select_helpers_multi(n, k, failed)
    return [Job(job_id=i, failed_node=f, requestor=f, helpers=helper_sets[i])
            for i, f in enumerate(failed)]


@given(multi_scenario())
@settings(max_examples=40, deadline=None)
def test_all_multi_planners_valid(sc):
    n, k, nf = sc
    jobs = _jobs(n, k, nf)
    for plan in (plan_msrepair(jobs), plan_mppr(jobs),
                 plan_random(jobs, seed=1)):
        validate_plan(plan)


@given(multi_scenario())
@settings(max_examples=30, deadline=None)
def test_msrepair_no_more_rounds_than_mppr(sc):
    n, k, nf = sc
    jobs = _jobs(n, k, nf)
    assert plan_msrepair(jobs).num_rounds <= plan_mppr(jobs).num_rounds


def test_helper_selection_maximizes_nr():
    """Paper: spread helper sets to maximize |NR| (RS(7,4), 2 failures:
    5 survivors, forced overlap 3, |NR| max = 2)."""
    hs = select_helpers_multi(7, 4, [0, 1])
    jobs = [Job(0, 0, 0, hs[0]), Job(1, 1, 1, hs[1])]
    r, nr, rp = node_sets(jobs)
    assert len(nr) == 2 and len(r) == 3
    # with >= 2k survivors the sets are disjoint (NR maximal, R empty)
    hs = select_helpers_multi(10, 3, [0, 1])
    assert not (set(hs[0]) & set(hs[1]))


def test_mppr_serializes_jobs():
    jobs = _jobs(6, 3, 2)
    plan = plan_mppr(jobs)
    # first half of the rounds only touches job 0, second half job 1
    half = plan.num_rounds // 2
    assert all(t.job == 0 for r in plan.rounds[:half] for t in r.transfers)
    assert all(t.job == 1 for r in plan.rounds[half:] for t in r.transfers)
