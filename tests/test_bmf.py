"""BMFRepair (Algorithm 1): pruned DFS correctness + optimization laws."""
import itertools

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.bmf import find_min_time_path, optimize_round, path_time
from repro.core.plan import Round, Transfer


def brute_force_best(src, dst, idle, bw, chunk):
    """Oracle: enumerate every relay permutation of every subset."""
    best = (src, dst)
    best_t = path_time(best, bw, chunk)
    for r in range(1, len(idle) + 1):
        for subset in itertools.permutations(idle, r):
            path = (src, *subset, dst)
            t = path_time(path, bw, chunk)
            if t < best_t:
                best, best_t = path, t
    return best, best_t


@given(st.integers(0, 500), st.integers(4, 7))
@settings(max_examples=60, deadline=None)
def test_dfs_matches_bruteforce(seed, n):
    bw = topology.heterogeneous_matrix(n, low=1, high=30, seed=seed)
    idle = list(range(2, n))
    want_path, want_t = brute_force_best(0, 1, idle, bw, 16.0)
    got_path, got_t = find_min_time_path(0, 1, idle, bw, 16.0, bound=np.inf)
    assert abs(got_t - want_t) < 1e-9
    assert abs(path_time(got_path, bw, 16.0) - want_t) < 1e-9


def test_paper_table1_example():
    """Paper section IV.A: with Table I bandwidths, chunk 20M, the P1->D3
    transfer (20/4 = 5s) reroutes through P2: P1->P2->D3 (20/6 + 20/10 =
    5.33s... the paper's narrative uses 2s+2s hops; with the Table I matrix
    the direct path is the optimum unless relays beat it — verify the
    search returns whichever is cheaper)."""
    _, bw = topology.table1_matrix()          # nodes D3,P1,P2,P3 = 0,1,2,3
    path, t = find_min_time_path(1, 0, [2, 3], bw, 20.0, bound=np.inf)
    want_path, want_t = brute_force_best(1, 0, [2, 3], bw, 20.0)
    assert abs(t - want_t) < 1e-9
    assert t <= 20.0 / bw[1, 0] + 1e-9        # never worse than direct


def test_pruning_bound_short_circuits():
    """With bound <= best possible, search returns the direct path."""
    bw = topology.uniform_matrix(5, 10.0)
    path, t = find_min_time_path(0, 1, [2, 3, 4], bw, 10.0, bound=0.5)
    assert path == (0, 1)


def _round(pairs, terms_start=0):
    return Round(transfers=[
        Transfer(src=s, dst=d, job=0, terms=frozenset({s}))
        for s, d in pairs
    ])


@given(st.integers(0, 300))
@settings(max_examples=40, deadline=None)
def test_optimize_never_increases_round_time(seed):
    n = 8
    bw = topology.heterogeneous_matrix(n, low=1, high=40, seed=seed)
    rnd = _round([(1, 0), (3, 2)])
    idle = [4, 5, 6, 7]
    new_rnd, stats = optimize_round(rnd, bw, idle, 16.0)
    before = max(path_time(t.path, bw, 16.0) for t in rnd.transfers)
    after = max(path_time(t.path, bw, 16.0) for t in new_rnd.transfers)
    assert after <= before + 1e-9
    # relays unique across the round and disjoint from endpoints
    used = []
    for t in new_rnd.transfers:
        used.extend(t.relays)
    assert len(used) == len(set(used))
    assert not (set(used) & {0, 1, 2, 3})


@given(st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_optimize_all_at_least_as_good(seed):
    n = 9
    bw = topology.heterogeneous_matrix(n, low=1, high=40, seed=seed)
    rnd = _round([(1, 0), (3, 2), (5, 4)])
    idle = [6, 7, 8]
    base, _ = optimize_round(rnd, bw, idle, 16.0)
    ext, _ = optimize_round(rnd, bw, idle, 16.0, optimize_all=True)
    total_base = sum(path_time(t.path, bw, 16.0) for t in base.transfers)
    total_ext = sum(path_time(t.path, bw, 16.0) for t in ext.transfers)
    assert total_ext <= total_base + 1e-9


def test_bmf_stats_report_savings():
    bw = np.array([
        [0, 1, 20, 20],
        [1, 0, 20, 20],
        [20, 20, 0, 20],
        [20, 20, 20, 0.0],
    ])
    rnd = _round([(0, 1)])
    new_rnd, stats = optimize_round(rnd, bw, [2, 3], 20.0)
    # direct 0->1 takes 20s; 0->2->1 takes 2s
    assert stats.improved_links == 1
    assert new_rnd.transfers[0].path in ((0, 2, 1), (0, 3, 1))
    assert stats.time_saved > 15.0


def test_bmf_stats_attribute_bottleneck_vs_extra():
    """`time_saved` splits into the Algorithm-1 bottleneck loop and the
    beyond-paper optimize_all pass, so ablations can attribute gains.
    (Twin of the non-hypothesis-gated version in test_planner_arrays.)"""
    bw = np.full((6, 6), 1.0)
    np.fill_diagonal(bw, 0.0)
    bw[0, 1] = 2.0                    # bottleneck: direct 10s
    bw[0, 4] = bw[4, 1] = 5.0         # ... 0->4->1 takes 8s, still worst
    bw[2, 3] = 4.0                    # secondary: direct 5s ...
    bw[2, 5] = bw[5, 3] = 20.0        # ... 2->5->3 takes 2s (extra pass)
    rnd = _round([(0, 1), (2, 3)])
    _, plain = optimize_round(rnd, bw, [4, 5], 20.0)
    assert plain.time_saved_bottleneck > 0
    assert plain.time_saved_extra == 0.0
    assert plain.time_saved == plain.time_saved_bottleneck
    _, both = optimize_round(rnd, bw, [4, 5], 20.0, optimize_all=True)
    assert both.time_saved_bottleneck == plain.time_saved_bottleneck
    assert both.time_saved_extra > 0
    assert both.time_saved == pytest.approx(
        both.time_saved_bottleneck + both.time_saved_extra)