"""Batched byte data plane: parity with the serial oracle, stripe
placements, PPT lowering, and the plan-relabeling transform."""
import numpy as np
import pytest

from repro.core import executor, topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.core.engine.arrays import (compile_plan, decompile,
                                      relabel_plan_nodes)
from repro.core.engine.dataplane import (execute_plans_batch,
                                         identity_block_map)
from repro.core.plan import Job, RepairPlan, Round, Transfer, validate_plan
from repro.core.ppt import build_ppt_tree, ppt_round_plan
from repro.core.simulator import Scenario, run_scheme
from repro.ec.rs import RSCode
from repro.ec.stripe import place_stripes
from repro.sim.suite import sample_failures
from repro.sim.sweep import _verify_plan

SINGLE = ("traditional", "ppr", "bmf", "bmf_static", "ppt")
MULTI = ("mppr", "random", "msrepair")


def _scenario(n, k, failed, seed, cluster):
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=seed,
                           mode="markov")
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=4.0)


def _plan_for(sc, scheme, seed):
    return _verify_plan(sc, scheme, seed, bmf_optimize_all=False)


def _exec_both(plan, code, cw, block_of=None):
    ser = executor.execute_plan(plan, code, cw, use_kernel=False,
                                block_of=block_of)
    bat = execute_plans_batch([plan], [code], [cw],
                              block_of=None if block_of is None
                              else [block_of], use_kernel=False)
    return ser, bat


# ------------------------------------------------------- scheme-sweep parity
@pytest.mark.parametrize("scheme", SINGLE)
def test_single_failure_schemes_byte_identical(scheme, rng):
    code = RSCode(6, 3)
    cw = code.encode(rng.integers(0, 256, size=(3, 640), dtype=np.uint8))
    sc = _scenario(6, 3, (2,), seed=4, cluster=12)
    plan = _plan_for(sc, scheme, 4)
    ser, bat = _exec_both(plan, code, cw)
    assert ser.verified and bool(bat.verified[0])
    assert int(bat.bytes_moved[0]) == ser.bytes_moved
    for jid, blk in ser.reconstructed.items():
        assert np.array_equal(bat.reconstructed[0][jid], np.asarray(blk))
        assert np.array_equal(bat.reconstructed[0][jid], cw[2])


@pytest.mark.parametrize("scheme", MULTI)
def test_multi_failure_schemes_byte_identical(scheme, rng):
    code = RSCode(7, 4)
    cw = code.encode(rng.integers(0, 256, size=(4, 384), dtype=np.uint8))
    sc = _scenario(7, 4, (1, 5), seed=9, cluster=12)
    plan = _plan_for(sc, scheme, 9)
    ser, bat = _exec_both(plan, code, cw)
    assert ser.verified and bool(bat.verified[0])
    assert int(bat.bytes_moved[0]) == ser.bytes_moved
    for j, f in enumerate((1, 5)):
        assert np.array_equal(bat.reconstructed[0][j], cw[f])


def test_mixed_batch_matches_serial_case_for_case(rng):
    """One heterogeneous batch (codes, clusters, schemes, job counts)
    equals running the serial oracle per case."""
    specs = [
        ((4, 2), (0,), "traditional", 8), ((6, 3), (1,), "ppr", 10),
        ((7, 4), (3,), "bmf", 12), ((6, 3), (0, 2), "msrepair", 11),
        ((7, 4), (0, 1), "mppr", 13), ((6, 3), (1, 4), "random", 9),
        ((6, 3), (5,), "ppt", 12), ((7, 4), (2,), "bmf_static", 14),
    ]
    plans, codes, cws, serials = [], [], [], []
    for i, ((n, k), failed, scheme, cluster) in enumerate(specs):
        code = RSCode(n, k)
        cw = code.encode(rng.integers(0, 256, size=(k, 256), dtype=np.uint8))
        sc = _scenario(n, k, failed, seed=20 + i, cluster=cluster)
        plan = _plan_for(sc, scheme, 20 + i)
        serials.append(executor.execute_plan(plan, code, cw,
                                             use_kernel=False))
        plans.append(compile_plan(plan))
        codes.append(code)
        cws.append(cw)
    bat = execute_plans_batch(plans, codes, cws, use_kernel=False)
    assert bat.all_verified
    for b, ser in enumerate(serials):
        assert ser.verified
        assert int(bat.bytes_moved[b]) == ser.bytes_moved
        for jid, blk in ser.reconstructed.items():
            assert np.array_equal(bat.reconstructed[b][jid],
                                  np.asarray(blk))


def test_kernel_interpret_path_matches_ref(rng):
    """The Pallas kernel path (interpret off-TPU) is byte-identical to
    the numpy ref path on the same batch."""
    code = RSCode(6, 3)
    cws, plans = [], []
    for i in range(3):
        cws.append(code.encode(
            rng.integers(0, 256, size=(3, 200), dtype=np.uint8)))
        sc = _scenario(6, 3, (i % 6,), seed=i, cluster=10)
        plans.append(compile_plan(_plan_for(sc, "ppr", i)))
    ref = execute_plans_batch(plans, code, cws, use_kernel=False)
    ker = execute_plans_batch(plans, code, cws, use_kernel=True)
    assert ref.all_verified and ker.all_verified
    for b in range(3):
        for jid in ref.reconstructed[b]:
            assert np.array_equal(ref.reconstructed[b][jid],
                                  ker.reconstructed[b][jid])


# -------------------------------------------------------- hypothesis sweep
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @given(
        code_i=st.integers(0, 2),
        pattern=st.sampled_from(("single", "double", "rack")),
        scheme_i=st.integers(0, 4),
        seed=st.integers(0, 2**16),
    )
    @settings(max_examples=30, deadline=None)
    def test_random_plans_byte_identical_property(code_i, pattern,
                                                  scheme_i, seed):
        """For random (code, failure pattern, scheme, seed) draws the
        batched data plane is byte-identical to the serial oracle and to
        `codeword[failed]` — every job, every scheme family."""
        n, k = ((6, 3), (7, 4), (6, 4))[code_i]
        rng = np.random.default_rng(seed)
        failed = sample_failures(rng, n, k, pattern)
        pool = SINGLE if len(failed) == 1 else MULTI
        scheme = pool[scheme_i % len(pool)]
        sc = _scenario(n, k, failed, seed=seed % 1024, cluster=n + 4)
        plan = _plan_for(sc, scheme, seed % 1024)
        code = RSCode(n, k)
        cw = code.encode(rng.integers(0, 256, size=(k, 160), dtype=np.uint8))
        ser, bat = _exec_both(plan, code, cw)
        assert ser.verified and bat.all_verified
        assert int(bat.bytes_moved[0]) == ser.bytes_moved
        for j, f in enumerate(failed):
            assert np.array_equal(bat.reconstructed[0][j], cw[f])
            assert np.array_equal(np.asarray(ser.reconstructed[j]), cw[f])


# ------------------------------------------------------------ PPT lowering
def test_ppt_round_plan_validates_and_folds(rng):
    sc = _scenario(6, 3, (0,), seed=7, cluster=12)
    tree = build_ppt_tree(sc.make_jobs()[0], sc.bw.matrix_at(0.0))
    plan = ppt_round_plan(tree)
    fanin = max((len(c) for c in tree.children.values()), default=1)
    validate_plan(plan, max_recv_per_round=max(fanin, 1))
    # deepest level sends first; the root ends holding every helper term
    assert plan.num_rounds == max(tree.depths().values())
    code = RSCode(6, 3)
    cw = code.encode(rng.integers(0, 256, size=(3, 512), dtype=np.uint8))
    ser, bat = _exec_both(plan, code, cw)
    assert ser.verified and bat.all_verified


# ------------------------------------------------- stripe placement replay
def test_placed_stripe_execution(rng):
    """Plans relabeled through a rotated `place_stripes` placement still
    reconstruct the placed stripe's lost block, batched and serial."""
    code = RSCode(6, 3)
    cluster = 11
    stripes = place_stripes(5, code, cluster)
    sc = _scenario(6, 3, (2,), seed=5, cluster=cluster)
    plan = compile_plan(_plan_for(sc, "bmf", 5))
    plans, cws, bmaps, serials = [], [], [], []
    for stripe in stripes:
        cw = code.encode(rng.integers(0, 256, size=(3, 333), dtype=np.uint8))
        pa = relabel_plan_nodes(plan, stripe.perm(cluster))
        bmap = stripe.block_map(cluster)
        serials.append(executor.execute_plan(
            decompile(pa), code, cw, use_kernel=False, block_of=bmap))
        plans.append(pa)
        cws.append(cw)
        bmaps.append(bmap)
    bat = execute_plans_batch(plans, code, cws, block_of=bmaps,
                              use_kernel=False)
    assert bat.all_verified
    for b, (stripe, ser) in enumerate(zip(stripes, serials)):
        assert ser.verified
        # relabeled requestor holds the *placed* failed block, block 2
        assert np.array_equal(bat.reconstructed[b][0], cws[b][2])


# --------------------------------------------------------------- relabeling
def test_relabel_plan_nodes_roundtrip(rng):
    sc = _scenario(7, 4, (0, 1), seed=3, cluster=12)
    pa = compile_plan(_plan_for(sc, "msrepair", 3))
    perm = np.roll(np.arange(12), 5)          # a nontrivial permutation
    out = relabel_plan_nodes(pa, perm)
    validate_plan(decompile(out))             # renaming preserves validity
    inv = np.argsort(perm)
    back = relabel_plan_nodes(out, inv)
    assert decompile(back) == decompile(pa)
    # original untouched
    assert int(pa.t_src[0]) != int(out.t_src[0]) or perm[pa.t_src[0]] == pa.t_src[0]


def test_relabel_rejects_bad_perms():
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    plan = RepairPlan(jobs=jobs, rounds=[Round(transfers=[
        Transfer(src=1, dst=0, job=0, terms=frozenset({1})),
        Transfer(src=2, dst=0, job=0, terms=frozenset({2})),
    ])])
    pa = compile_plan(plan)
    with pytest.raises(ValueError, match="cover"):
        relabel_plan_nodes(pa, np.array([0, 1]))          # too short
    with pytest.raises(ValueError, match="injective"):
        relabel_plan_nodes(pa, np.array([0, 1, 1]))       # collision


# --------------------------------------------------- executable invariants
def test_batched_consumed_source_raises(rng):
    """A later round sourcing a buffer consumed earlier is unexecutable:
    the batched engine refuses it instead of moving zeros."""
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    bad = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1}))]),
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1}))]),   # 1 already sent
    ])
    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 64), dtype=np.uint8))
    with pytest.raises(ValueError, match="holds no buffer"):
        execute_plans_batch([bad], [code], [cw], use_kernel=False)


def test_batched_incomplete_plan_not_verified(rng):
    """A structurally fine but incomplete plan (requestor never receives
    everything) is reported unverified, not crashed."""
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    partial = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=1, dst=0, job=0,
                                  terms=frozenset({1}))]),
    ])
    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 64), dtype=np.uint8))
    res = execute_plans_batch([partial], [code], [cw], use_kernel=False)
    assert not res.all_verified


def test_unplaced_block_raises_both_paths(rng):
    """A placement that leaves a failed/helper node without a block must
    fail loudly on both paths — -1 wrapping into python negative indexing
    would 'repair' the wrong block and self-consistently verify it."""
    code = RSCode(4, 2)
    cw = code.encode(rng.integers(0, 256, size=(2, 64), dtype=np.uint8))
    jobs = [Job(job_id=0, failed_node=0, requestor=0, helpers=(1, 2))]
    plan = RepairPlan(jobs=jobs, rounds=[
        Round(transfers=[Transfer(src=1, dst=2, job=0,
                                  terms=frozenset({1}))]),
        Round(transfers=[Transfer(src=2, dst=0, job=0,
                                  terms=frozenset({1, 2}))]),
    ])
    bad_map = np.array([-1, 1, 2, 3])      # failed node 0 unplaced
    with pytest.raises(ValueError, match="holds no block"):
        executor.execute_plan(plan, code, cw, use_kernel=False,
                              block_of=bad_map)
    with pytest.raises(ValueError, match="holds no block"):
        execute_plans_batch([plan], [code], [cw], block_of=[bad_map],
                            use_kernel=False)


def test_stripe_placement_accessors():
    code = RSCode(4, 2)
    [s0, s1] = place_stripes(2, code, 6)
    assert s1.node_ids == (4, 5, 0, 1)     # rotated placement
    bmap = s1.block_map(6)
    assert bmap.tolist() == [2, 3, -1, -1, 0, 1]
    perm = s1.perm(6)
    assert perm.tolist() == [4, 5, 0, 1, 2, 3]
    assert sorted(perm.tolist()) == list(range(6))   # a permutation
    with pytest.raises(ValueError, match="domains"):
        s1.block_map(3)


def test_identity_block_map():
    m = identity_block_map(6, 4)
    assert m.tolist() == [0, 1, 2, 3, -1, -1]
    assert identity_block_map(2, 4).tolist() == [0, 1, 2, 3]
