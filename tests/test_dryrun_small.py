"""Reduced-mesh dry-run smoke: lower+compile reduced configs on a (2,2,2)
pod/data/model mesh in a subprocess with 8 host devices. Exercises the same
code path as launch/dryrun.py without the 512-device compile cost."""
import subprocess
import sys
import textwrap

import pytest

_TEMPLATE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_test_mesh
from repro.models import model as M
from repro.models.sharding import MeshRules, tree_shardings
from repro.serve import serve_step as S
from repro.train import train_step as T
from repro.train.optimizer import AdamWConfig

arch = {arch!r}
kind = {kind!r}
cfg = get_arch(arch).reduced()
mesh = make_test_mesh(multi_pod=True, data=2, model=2)
rules = MeshRules(mesh=mesh, fsdp=("pod", "data"), tensor="model")
key = jax.random.PRNGKey(0)

if kind == "train":
    shape = ShapeConfig("t", "train", 16, 8)
    tcfg = T.TrainConfig(adamw=AdamWConfig(), microbatches=2, attn_chunk=8)
    state_struct = jax.eval_shape(lambda: T.init_state(key, cfg, tcfg))
    state_sh = tree_shardings(rules, state_struct,
                              T.state_logical(cfg, tcfg, rules))
    batch_struct = M.input_specs(cfg, shape)
    batch_sh = tree_shardings(rules, batch_struct, M.batch_logical(cfg, shape))
    step = T.make_train_step(cfg, tcfg, rules)
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None)).lower(
            state_struct, batch_struct)
        compiled = lowered.compile()
else:
    shape = ShapeConfig("d", "decode", 32, 8)
    params_struct = jax.eval_shape(lambda: M.init_params(key, cfg))
    params_sh = tree_shardings(rules, params_struct,
                               M.logical_params(cfg, rules))
    cache_struct = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len, rules))
    cache_sh = tree_shardings(rules, cache_struct, M.cache_logical(cfg))
    step_fn = S.make_decode_step(cfg, rules, 16)
    token = jax.ShapeDtypeStruct((shape.global_batch,), jax.numpy.int32)
    with mesh:
        lowered = jax.jit(step_fn, in_shardings=(params_sh, None, cache_sh)
                          ).lower(params_struct, token, cache_struct)
        compiled = lowered.compile()
ma = compiled.memory_analysis()
assert compiled.as_text()
print("OK", arch, kind, ma.temp_size_in_bytes)
"""


def _run(arch, kind):
    code = _TEMPLATE.format(arch=arch, kind=kind)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".", timeout=420)
    assert out.returncode == 0, (arch, kind, out.stderr[-3000:])
    assert "OK" in out.stdout


@pytest.mark.parametrize("arch", [
    "qwen2_15b", "grok1_314b", "smollm_360m", "gemma3_4b", "whisper_medium",
    "rwkv6_16b", "zamba2_7b", "qwen2vl_2b",
])
def test_reduced_train_lowers_on_multipod_mesh(arch):
    _run(arch, "train")


@pytest.mark.parametrize("arch", ["qwen2_15b", "rwkv6_16b", "zamba2_7b"])
def test_reduced_decode_lowers_on_multipod_mesh(arch):
    _run(arch, "decode")
