"""MeshRules logical->PartitionSpec translation (subprocess mesh)."""
import subprocess
import sys
import textwrap


def test_spec_translation_rules():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.models.sharding import MeshRules
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        r = MeshRules(mesh=mesh, fsdp=("pod", "data"), tensor="model")
        # divisible dims shard
        assert r.spec(("d", "tp"), (8, 4)) == P(("pod", "data"), "model")
        # non-divisible dims replicate (smollm 15-heads case)
        assert r.spec(("d", "tp"), (8, 15)) == P(("pod", "data"), None)
        assert r.spec(("d", "tp"), (9, 4)) == P(None, "model")
        # batch/seq aliases
        assert r.spec(("batch", None, "seq"), (8, 3, 16)) == \\
            P(("pod", "data"), None, "model")
        # an axis is used at most once per spec
        assert r.spec(("tp", "tp"), (4, 4)) == P("model", None)
        # scalars
        assert r.spec((), ()) == P()
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_no_mesh_rules_are_noop():
    from repro.models.sharding import NO_MESH
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert NO_MESH.constrain(x, ("batch", None)) is x
