"""JAX stepper parity: `run_sweep(executor="jax")` and the underlying
`repro.core.engine.jax_stepper` programs must reproduce the reference
engines — all 8 schemes, all three volatility regimes, 1e-6 relative
tolerance with identical round counts and relay hops — and must fall
back to the numpy vectorized engine cleanly when jax is unusable."""
import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel
from repro.core.engine.vectorized import run_scheme_vectorized
from repro.core.simulator import ALL_SCHEMES, Scenario, run_scheme
from repro.ec.rs import RSCode
from repro.sim.suite import MonteCarloSuite, SampleSpace, TraceSuite
from repro.sim.sweep import run_sweep

jax_stepper = pytest.importorskip(
    "repro.core.engine.jax_stepper", reason="engine package unavailable")
_HAS_JAX = jax_stepper.jax_available()

RTOL = 1e-6
MULTI = ("mppr", "random", "msrepair")


def _scenario(n=7, k=4, failed=(0,), seed=0, cluster=10, chunk=8.0,
              interval=2.0, mode="markov"):
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=interval, seed=seed,
                           mode=mode)
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk)


def _assert_parity(ref, got, label=""):
    assert got.num_rounds == ref.num_rounds, label
    assert got.relay_hops == ref.relay_hops, label
    assert got.total_time == pytest.approx(ref.total_time, rel=RTOL), label
    for a, b in zip(ref.round_times, got.round_times):
        assert b == pytest.approx(a, rel=RTOL, abs=1e-9), label


# ------------------------------------------------------------ parity matrix
@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
@pytest.mark.parametrize("mode", ["jitter", "redraw", "markov"])
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_jax_matches_reference_all_schemes_all_regimes(scheme, mode):
    failed = (0, 1) if scheme in MULTI else (0,)
    seeds = list(range(4))
    scs = [_scenario(failed=failed, seed=s, mode=mode) for s in seeds]
    ref = [run_scheme(sc, scheme, random_seed=s)
           for s, sc in zip(seeds, scs)]
    got = run_scheme_vectorized(scs, scheme, seeds=seeds, backend="jax")
    for s, (a, b) in enumerate(zip(ref, got)):
        _assert_parity(a, b, f"{scheme}/{mode} seed={s}")
        assert b.log == a.log, f"{scheme}/{mode} seed={s}"
        assert b.plan == a.plan, f"{scheme}/{mode} seed={s}"


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_static_network_and_traces():
    static = [_scenario(seed=s, interval=None) for s in range(3)]
    for scheme in ("ppr", "bmf", "ppt"):
        for a, b in zip([run_scheme(sc, scheme) for sc in static],
                        run_scheme_vectorized(static, scheme,
                                              backend="jax")):
            _assert_parity(a, b, f"static {scheme}")
    for cycle in (True, False):
        traced = [
            Scenario(
                num_nodes=sc.num_nodes, code=sc.code, failed=sc.failed,
                bw=BandwidthTrace.record(sc.bw, 16, cycle=cycle),
                ingress=sc.ingress, chunk_mb=sc.chunk_mb,
            )
            for sc in (_scenario(seed=s) for s in range(3))
        ]
        for scheme in ("traditional", "ppr", "ppt", "bmf"):
            for a, b in zip([run_scheme(sc, scheme) for sc in traced],
                            run_scheme_vectorized(traced, scheme,
                                                  backend="jax")):
                _assert_parity(a, b, f"trace cycle={cycle} {scheme}")


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_epoch_horizon_grows_and_restores_replans(monkeypatch):
    """A live case outrunning the pre-sampled horizon must re-run with a
    doubled horizon — including rolling back BMF splices the aborted
    attempt wrote — and still match the reference engine exactly."""
    monkeypatch.setattr(jax_stepper, "_INITIAL_LIVE_EPOCHS", 2)
    grown: list[int] = []
    orig = jax_stepper._EngineBase.grow

    def spy(self):
        grown.append(self.live_epochs)
        return orig(self)

    monkeypatch.setattr(jax_stepper._EngineBase, "grow", spy)
    scs = [_scenario(n=4, k=2, seed=s, cluster=6, chunk=64.0)
           for s in range(2)]
    for scheme in ("bmf", "ppt"):       # replanned rounds + pipeline
        ref = [run_scheme(sc, scheme) for sc in scs]
        got = run_scheme_vectorized(scs, scheme, backend="jax")
        for a, b in zip(ref, got):
            _assert_parity(a, b, f"horizon {scheme}")
    assert grown, "the 2-epoch horizon must have overflowed"


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_sweep_matches_serial():
    space = SampleSpace(
        codes=((4, 2), (6, 3)), cluster_sizes=(8,), chunk_mb=(8.0,),
        regimes=("hot2s", "redraw2s"), failure_patterns=("single", "double"),
    )
    suite = MonteCarloSuite("jaxparity", 12, space, base_seed=11)
    serial = run_sweep(suite, executor="serial")
    jaxs = run_sweep(suite, executor="jax")
    assert len(jaxs.cases) == 12
    for cs, cj in zip(serial.cases, jaxs.cases):
        assert set(cs.results) == set(cj.results)
        for scheme in cs.results:
            a, b = cs.results[scheme], cj.results[scheme]
            assert b.num_rounds == a.num_rounds, (cs.index, scheme)
            assert b.relay_hops == a.relay_hops, (cs.index, scheme)
            assert b.total_time == pytest.approx(a.total_time, rel=RTOL), \
                (cs.index, scheme)


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_sweep_on_frozen_traces_matches_serial():
    space = SampleSpace(codes=((6, 3),), cluster_sizes=(8,), chunk_mb=(8.0,),
                        regimes=("hot2s",), failure_patterns=("single",))
    frozen = TraceSuite.freeze(
        MonteCarloSuite("p", 6, space, base_seed=5), num_epochs=64)
    serial = run_sweep(frozen, executor="serial")
    jaxs = run_sweep(frozen, executor="jax")
    for cs, cj in zip(serial.cases, jaxs.cases):
        for scheme in cs.results:
            assert (cj.results[scheme].total_time
                    == pytest.approx(cs.results[scheme].total_time,
                                     rel=RTOL))


# ------------------------------------------------------------ fallback paths
def test_jax_missing_falls_back_to_numpy_with_warning(monkeypatch):
    """The no-jax path: executor='jax' must warn once and produce the
    numpy vectorized engine's (identical) results."""
    monkeypatch.setattr(jax_stepper, "_JAX_OK", False)
    scs = [_scenario(seed=s, cluster=8) for s in range(2)]
    ref = run_scheme_vectorized(scs, "ppr")
    with pytest.warns(RuntimeWarning, match="jax is not importable"):
        got = run_scheme_vectorized(scs, "ppr", backend="jax")
    for a, b in zip(ref, got):
        assert b.total_time == a.total_time


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_non_persistent_shares_fall_back():
    """Epoch-keyed Dirichlet redraws cannot be pretabulated on device:
    the factory must decline and the batch must still match serial."""
    m = topology.heterogeneous_matrix(8, low=3, high=30, seed=2)
    scs = [
        Scenario(num_nodes=8, code=RSCode(6, 3), failed=(0,),
                 bw=BandwidthProcess(base=m, change_interval=2.0, seed=s,
                                     mode="markov"),
                 ingress=IngressModel(seed=s, persistent_shares=False),
                 chunk_mb=8.0)
        for s in range(2)
    ]
    assert jax_stepper.make_round_engine(scs, 8, []) is None
    ref = [run_scheme(sc, "traditional") for sc in scs]
    got = run_scheme_vectorized(scs, "traditional", backend="jax")
    for a, b in zip(ref, got):
        _assert_parity(a, b, "non-persistent fallback")


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_jax_unsupported_helper_ids_fall_back_per_case():
    """Helper ids >= 64 cannot be bitmask-compiled; those cases must drop
    to the object engine while the rest of the batch runs on device."""
    m = topology.heterogeneous_matrix(70, low=3, high=30, seed=1)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=1, mode="markov")
    big = Scenario(num_nodes=70, code=RSCode(6, 3), failed=(0,), bw=bwp,
                   ingress=IngressModel(seed=1), chunk_mb=4.0,
                   helpers=((65, 66, 67),))
    small = _scenario(n=6, k=3, seed=1, cluster=8, chunk=4.0)
    got = run_scheme_vectorized([big, small], "ppr", backend="jax")
    ref = [run_scheme(big, "ppr"), run_scheme(small, "ppr")]
    for a, b in zip(ref, got):
        _assert_parity(a, b, "fallback")


@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_bucketing_shares_compiled_programs():
    """Batches whose raw shapes differ only within a pow2 bucket must pad
    to the same padded shapes (one compiled program per (N, bucket))."""
    assert jax_stepper._pow2(0) == 1
    assert jax_stepper._pow2(1) == 1
    assert jax_stepper._pow2(3) == 4
    assert jax_stepper._pow2(8) == 8
    scs3 = [_scenario(seed=s, cluster=8) for s in range(3)]
    scs4 = [_scenario(seed=s, cluster=8) for s in range(4)]
    e3 = jax_stepper.make_round_engine(scs3, 8, [])
    e4 = jax_stepper.make_round_engine(scs4, 8, [])
    assert e3.Bp == e4.Bp == 4
    hop_u = np.zeros((3, 2, 1), dtype=np.int64)
    n_hops = np.zeros((3, 2), dtype=np.int64)
    hu, hv, nh, tt = e3._pad_round(hop_u, hop_u, n_hops, np.zeros(3))
    assert hu.shape == (4, 2, 1) and tt.shape == (4,)


# ------------------------------------------------------ program-cache reuse
@pytest.mark.skipif(not _HAS_JAX, reason="jax not installed")
def test_compiled_programs_reused_across_run_sweep_calls():
    """The jit steppers live in a module-global cache keyed by padded
    pow2 shapes: a second `run_sweep(executor="jax")` over a fresh but
    same-shaped suite must trigger ZERO new XLA compilations (this is
    what makes the jax executor amortizable at all — and what "auto"
    relies on when routing repeated trace sweeps to it)."""
    import logging

    import jax

    space = SampleSpace(codes=((6, 3),), cluster_sizes=(8,), chunk_mb=(8.0,),
                        regimes=("hot2s",), failure_patterns=("single",))

    def make():
        return TraceSuite.freeze(
            MonteCarloSuite("reuse", 5, space, base_seed=21), num_epochs=32)

    run_sweep(make(), executor="jax")          # warm every program shape

    compiles: list[str] = []

    class Spy(logging.Handler):
        def emit(self, record):
            msg = record.getMessage()
            if "Finished XLA compilation" in msg:
                compiles.append(msg)

    spy = Spy(level=logging.WARNING)
    logger = logging.getLogger("jax")
    old_level = logger.level
    jax.config.update("jax_log_compiles", True)
    logger.addHandler(spy)
    logger.setLevel(logging.WARNING)
    try:
        second = run_sweep(make(), executor="jax")
    finally:
        logger.removeHandler(spy)
        logger.setLevel(old_level)
        jax.config.update("jax_log_compiles", False)
    assert len(second.cases) == 5
    assert not compiles, f"recompiled across run_sweep calls: {compiles}"
