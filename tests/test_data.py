"""Synthetic pipeline: determinism, structure, host sharding."""
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, SyntheticStream


def test_deterministic_by_step():
    cfg = get_arch("qwen2_15b").reduced()
    s = SyntheticStream(cfg, ShapeConfig("t", "train", 16, 4))
    a = s.batch_at(3)
    b = SyntheticStream(cfg, ShapeConfig("t", "train", 16, 4)).batch_at(3)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = s.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_shifted():
    cfg = get_arch("qwen2_15b").reduced()
    s = SyntheticStream(cfg, ShapeConfig("t", "train", 16, 4))
    b = s.batch_at(0)
    assert b["labels"].shape == b["tokens"].shape
    # bigram structure: every label is one of the token's successors
    succ = s.successors
    tok, lab = b["tokens"], b["labels"]
    ok = np.zeros(tok.shape, bool)
    for j in range(succ.shape[1]):
        ok |= succ[tok, j] == lab
    assert ok.all()


def test_host_sharding_partitions_global_batch():
    cfg = get_arch("qwen2_15b").reduced()
    s = SyntheticStream(cfg, ShapeConfig("t", "train", 16, 8))
    full = s.batch_at(0)
    parts = [s.host_batch_at(0, h, 4) for h in range(4)]
    got = np.concatenate([p["tokens"] for p in parts], axis=0)
    assert np.array_equal(got, full["tokens"])


def test_modalities_present():
    vl = get_arch("qwen2vl_2b").reduced()
    b = SyntheticStream(vl, ShapeConfig("t", "train", 16, 2)).batch_at(0)
    assert b["pos3"].shape == (3, 2, 16)
    assert b["vision_embeds"].shape[0] == 2
    wh = get_arch("whisper_medium").reduced()
    b = SyntheticStream(wh, ShapeConfig("t", "train", 16, 2)).batch_at(0)
    assert b["frames"].shape == (2, 16, wh.d_model)
    assert b["tokens"].shape[1] == min(wh.max_decoder_len, 16)
