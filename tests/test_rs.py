"""RS(n,k) MDS property: any <= n-k erasures decode (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ec.rs import RSCode, generator_matrix


@st.composite
def rs_scenario(draw):
    k = draw(st.integers(2, 6))
    n = draw(st.integers(k + 1, min(k + 4, 10)))
    f = draw(st.integers(1, n - k))
    failed = draw(st.permutations(range(n)))[:f]
    seed = draw(st.integers(0, 2**16))
    return n, k, sorted(failed), seed


@given(rs_scenario())
@settings(max_examples=60, deadline=None)
def test_any_erasure_pattern_decodes(sc):
    n, k, failed, seed = sc
    rng = np.random.default_rng(seed)
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 96), dtype=np.uint8)
    cw = code.encode(data)
    helpers = [i for i in range(n) if i not in failed][:k]
    rec = code.reconstruct(failed, helpers, cw[helpers])
    assert np.array_equal(rec, cw[failed])


@given(rs_scenario())
@settings(max_examples=30, deadline=None)
def test_decode_all_recovers_data(sc):
    n, k, failed, seed = sc
    rng = np.random.default_rng(seed)
    code = RSCode(n, k)
    data = rng.integers(0, 256, size=(k, 64), dtype=np.uint8)
    cw = code.encode(data)
    present = {i: cw[i] for i in range(n) if i not in failed}
    rec = code.decode_all(present)
    assert np.array_equal(rec, data)


def test_generator_systematic():
    for n, k in [(4, 2), (6, 3), (7, 4), (6, 4), (4, 3), (9, 6)]:
        g = generator_matrix(n, k)
        assert np.array_equal(g[:k], np.eye(k, dtype=np.uint8))


def test_repair_coeffs_validate_helpers():
    code = RSCode(6, 3)
    try:
        code.repair_coeffs((0,), (1, 2))
        assert False, "should require k helpers"
    except ValueError:
        pass
    try:
        code.repair_coeffs((0,), (0, 1, 2))
        assert False, "helpers cannot include failed"
    except ValueError:
        pass
