"""Vectorized engine parity: the batched array steppers must reproduce the
object-based reference engine case for case, across every scheme."""
import numpy as np
import pytest

from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, BandwidthTrace, IngressModel
from repro.core.engine.vectorized import run_scheme_vectorized
from repro.core.simulator import (ALL_SCHEMES, Scenario, run_scheme)
from repro.ec.rs import RSCode
from repro.sim.suite import MonteCarloSuite, SampleSpace, TraceSuite
from repro.sim.sweep import run_sweep

RTOL = 1e-6


def _scenario(n=6, k=3, failed=(0,), seed=0, cluster=8, chunk=8.0,
              interval=2.0, mode="markov"):
    m = topology.heterogeneous_matrix(cluster, low=3, high=30, seed=seed)
    bwp = BandwidthProcess(base=m, change_interval=interval, seed=seed,
                           mode=mode)
    return Scenario(num_nodes=cluster, code=RSCode(n, k), failed=failed,
                    bw=bwp, ingress=IngressModel(seed=seed), chunk_mb=chunk)


def _assert_result_parity(ref, got, label=""):
    assert got.num_rounds == ref.num_rounds, label
    assert got.relay_hops == ref.relay_hops, label
    assert got.total_time == pytest.approx(ref.total_time, rel=RTOL), label
    for a, b in zip(ref.round_times, got.round_times):
        assert b == pytest.approx(a, rel=RTOL, abs=1e-9), label


# ------------------------------------------------------ per-scheme batches
@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_scheme_batch_matches_reference(scheme):
    failed = (0, 1) if scheme in ("mppr", "random", "msrepair") else (0,)
    seeds = list(range(8))
    scs = [_scenario(n=7, k=4, failed=failed, seed=s, cluster=10)
           for s in seeds]
    ref = [run_scheme(sc, scheme, random_seed=s)
           for s, sc in zip(seeds, scs)]
    got = run_scheme_vectorized(scs, scheme, seeds=seeds)
    for s, (a, b) in enumerate(zip(ref, got)):
        _assert_result_parity(a, b, f"{scheme} seed={s}")
        assert b.log == a.log, f"{scheme} seed={s}"
        assert b.plan == a.plan, f"{scheme} seed={s}"


def test_bmf_optimize_all_parity():
    scs = [_scenario(n=6, k=3, seed=s, cluster=10) for s in range(4)]
    ref = [run_scheme(sc, "bmf", bmf_optimize_all=True) for sc in scs]
    got = run_scheme_vectorized(scs, "bmf", bmf_optimize_all=True)
    for a, b in zip(ref, got):
        _assert_result_parity(a, b, "bmf optimize_all")


def test_static_network_and_trace_parity():
    static = [_scenario(seed=s, interval=None) for s in range(3)]
    for scheme in ("ppr", "bmf", "ppt"):
        for a, b in zip([run_scheme(sc, scheme) for sc in static],
                        run_scheme_vectorized(static, scheme)):
            _assert_result_parity(a, b, f"static {scheme}")
    traced = [
        Scenario(
            num_nodes=sc.num_nodes, code=sc.code, failed=sc.failed,
            bw=BandwidthTrace.record(sc.bw, 64), ingress=sc.ingress,
            chunk_mb=sc.chunk_mb,
        )
        for sc in (_scenario(seed=s) for s in range(4))
    ]
    for scheme in ("traditional", "ppr", "ppt", "bmf"):
        for a, b in zip([run_scheme(sc, scheme) for sc in traced],
                        run_scheme_vectorized(traced, scheme)):
            _assert_result_parity(a, b, f"trace {scheme}")


def test_mixed_cluster_sizes_group_and_match():
    """Cases with different N / round structures split into compatible
    batches internally but still come back in input order."""
    scs = ([_scenario(n=4, k=2, seed=s, cluster=6) for s in range(3)]
           + [_scenario(n=7, k=4, seed=s, cluster=12) for s in range(3)])
    got = run_scheme_vectorized(scs, "bmf", seeds=[0] * 6)
    ref = [run_scheme(sc, "bmf") for sc in scs]
    for a, b in zip(ref, got):
        _assert_result_parity(a, b, "mixed")


# -------------------------------------------------- acceptance-scale sweep
def test_vectorized_sweep_matches_serial_50_scenarios():
    """>= 50 randomized Monte-Carlo scenarios spanning single- and
    multi-failure scheme families: executor="vectorized" must match the
    object engine within 1e-6 relative on total_time, with identical
    round counts and relay hops."""
    space = SampleSpace(
        codes=((4, 2), (6, 3), (7, 4)), cluster_sizes=(8, 10),
        chunk_mb=(8.0,), regimes=("hot2s", "cold5s", "redraw2s"),
        failure_patterns=("single", "double", "rack"),
    )
    suite = MonteCarloSuite("parity", 50, space, base_seed=11)
    serial = run_sweep(suite, executor="serial")
    vec = run_sweep(suite, executor="vectorized")
    assert len(vec.cases) == 50
    schemes_seen = set()
    for cs, cv in zip(serial.cases, vec.cases):
        assert set(cs.results) == set(cv.results)
        for scheme in cs.results:
            schemes_seen.add(scheme)
            a, b = cs.results[scheme], cv.results[scheme]
            assert b.num_rounds == a.num_rounds, (cs.index, scheme)
            assert b.relay_hops == a.relay_hops, (cs.index, scheme)
            assert b.total_time == pytest.approx(a.total_time, rel=RTOL), \
                (cs.index, scheme)
    # the suite exercises both evaluation families
    assert {"traditional", "ppr", "ppt", "bmf"} <= schemes_seen
    assert {"mppr", "random", "msrepair"} <= schemes_seen


def test_vectorized_sweep_on_frozen_traces_matches_serial():
    space = SampleSpace(codes=((6, 3),), cluster_sizes=(8,), chunk_mb=(8.0,),
                        regimes=("hot2s",), failure_patterns=("single",))
    frozen = TraceSuite.freeze(
        MonteCarloSuite("p", 8, space, base_seed=5), num_epochs=64)
    serial = run_sweep(frozen, executor="serial")
    vec = run_sweep(frozen, executor="vectorized")
    for cs, cv in zip(serial.cases, vec.cases):
        for scheme in cs.results:
            assert (cv.results[scheme].total_time
                    == pytest.approx(cs.results[scheme].total_time, rel=RTOL))


def test_vectorized_sweep_keep_plans_and_stats():
    suite = MonteCarloSuite(
        "kp", 6,
        SampleSpace(codes=((6, 3),), cluster_sizes=(8,), chunk_mb=(8.0,),
                    regimes=("hot2s",), failure_patterns=("single",)),
        base_seed=2)
    sweep = run_sweep(suite, executor="vectorized", keep_plans=True)
    for case in sweep.cases:
        for scheme in ("ppr", "bmf"):
            r = case.results[scheme]
            assert r.plan is not None and r.plan.num_rounds == r.num_rounds
    st = sweep.stats("bmf")
    assert st.count == 6 and np.isfinite(st.mean)
    assert (sweep.speedups("ppr", "bmf") > 0).all()


def test_unsupported_helper_ids_fall_back_per_case():
    """Helper (term) ids >= 64 cannot be bitmask-compiled; those cases
    must fall back to the object engine transparently while the rest of
    the batch stays vectorized."""
    from repro.core.engine.arrays import UnsupportedPlanError, compile_plan
    from repro.core.simulator import plan_for_scheme

    m = topology.heterogeneous_matrix(70, low=3, high=30, seed=1)
    bwp = BandwidthProcess(base=m, change_interval=2.0, seed=1, mode="markov")
    big = Scenario(num_nodes=70, code=RSCode(6, 3), failed=(0,), bw=bwp,
                   ingress=IngressModel(seed=1), chunk_mb=4.0,
                   helpers=((65, 66, 67),))
    # the fixture really is uncompilable — guard against silent drift
    with pytest.raises(UnsupportedPlanError):
        compile_plan(plan_for_scheme("ppr", big.make_jobs()))
    small = _scenario(n=6, k=3, seed=1, cluster=8, chunk=4.0)
    got = run_scheme_vectorized([big, small], "ppr")
    ref = [run_scheme(big, "ppr"), run_scheme(small, "ppr")]
    for a, b in zip(ref, got):
        _assert_result_parity(a, b, "fallback")
        assert b.plan == a.plan


def test_seeds_length_mismatch_raises():
    with pytest.raises(ValueError):
        run_scheme_vectorized([_scenario()], "ppr", seeds=[0, 1])


def test_integer_chunk_sizes_parity():
    """Benchmark grids pass chunk_mb as python ints; the batched state
    arrays must not silently become integer-typed (regression)."""
    scs = [_scenario(seed=s, chunk=16) for s in range(3)]      # int chunk
    for scheme in ("traditional", "ppr", "bmf", "ppt"):
        ref = [run_scheme(sc, scheme) for sc in scs]
        got = run_scheme_vectorized(scs, scheme)
        for a, b in zip(ref, got):
            _assert_result_parity(a, b, f"int-chunk {scheme}")
