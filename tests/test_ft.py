"""Fault-tolerance: failure injection, stragglers, end-to-end recovery."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ECCheckpointConfig, ECCheckpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core import topology
from repro.core.bandwidth import BandwidthProcess, IngressModel
from repro.data.pipeline import SyntheticStream
from repro.ft.failures import FailureEvent, FailureInjector, StragglerMonitor
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def test_injector_deterministic_and_bounded():
    inj = FailureInjector(num_domains=8, rate_per_step=0.3, seed=5)
    seq1 = [inj.check(s) for s in range(200)]
    seq2 = [inj.check(s) for s in range(200)]
    assert [e and e.domains for e in seq1] == [e and e.domains for e in seq2]
    events = [e for e in seq1 if e]
    assert events, "rate 0.3 over 200 steps must fire"
    for e in events:
        assert 1 <= len(e.domains) <= 2
        assert all(0 <= d < 8 for d in e.domains)


def test_injector_scheduled():
    inj = FailureInjector(num_domains=8,
                          scheduled=(FailureEvent(step=7, domains=(2, 3)),))
    assert inj.check(6) is None
    assert inj.check(7).domains == (2, 3)


def test_straggler_monitor():
    mon = StragglerMonitor(num_hosts=4, min_steps=3)
    for step in range(6):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 2.5)
    assert mon.stragglers() == [2]


def test_end_to_end_failure_recovery():
    """Train, checkpoint, lose 2 domains, repair, resume — losses continue
    from where they left off."""
    cfg = get_arch("smollm_360m").reduced()
    shape = ShapeConfig("t", "train", 32, 8)
    tcfg = TrainConfig(adamw=AdamWConfig(peak_lr=5e-3, warmup_steps=5),
                       attn_chunk=16)
    d = tempfile.mkdtemp()
    try:
        _, bwm = topology.tpu_pod_dcn_matrix(8, 1)
        ck = ECCheckpointer(
            ECCheckpointConfig(directory=d, n=6, k=4, chunk_bytes=1 << 14,
                               num_domains=8),
            bw=BandwidthProcess(base=bwm, change_interval=2.0, mode="markov"),
            ingress=IngressModel(),
        )
        state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = jax.jit(make_train_step(cfg, tcfg))
        stream = SyntheticStream(cfg, shape)
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
            state, m = step(state, batch)
        ck.save(10, state, wait=True)
        loss_10 = float(m["loss"])

        # two domains die; restore and continue
        restored, report = ck.load(state, lost_domains=(0, 4))
        assert report.blocks_repaired > 0
        assert int(np.asarray(restored["step"])) == 10
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(10).items()}
        state2, m2 = step(restored, batch)
        # resumed training is exactly the run we would have had
        state_direct, m_direct = step(state, batch)
        assert abs(float(m2["loss"]) - float(m_direct["loss"])) < 1e-5
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_elastic_batch_resizing():
    from repro.ft.elastic import elastic_data_size
    assert elastic_data_size(256, 16, 14) == 224
    assert elastic_data_size(256, 16, 1) == 16
