"""HLO analyzer: trip-count multiplication against known-FLOP modules."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis


def test_scan_flops_multiplied_by_trips():
    """scan of L matmuls: analyzer must report L * 2mnk, not 2mnk."""
    L, m, k, n = 6, 8, 32, 16

    def f(w, x):
        def body(c, w_l):
            return jnp.dot(c, w_l), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    w = jnp.zeros((L, k, k), jnp.float32)   # square so carry shape fixed
    x = jnp.zeros((m, k), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    r = hlo_analysis.analyze(compiled.as_text())
    want = L * 2 * m * k * k
    assert abs(r["flops_per_device"] - want) / want < 0.05, r


def test_plain_matmul_flops_exact():
    m, k, n = 64, 128, 32

    def f(a, b):
        return jnp.dot(a, b)

    compiled = jax.jit(f).lower(
        jnp.zeros((m, k)), jnp.zeros((k, n))).compile()
    r = hlo_analysis.analyze(compiled.as_text())
    assert r["flops_per_device"] == 2 * m * k * n


def test_nested_scan_multiplies():
    Lo, Li, d = 3, 4, 16

    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ jnp.eye(d)), None
            ci, _ = jax.lax.scan(inner, c, None, length=Li)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=Lo)
        return y.sum()

    compiled = jax.jit(f).lower(jnp.zeros((8, d))).compile()
    r = hlo_analysis.analyze(compiled.as_text())
    want = Lo * Li * 2 * 8 * d * d
    assert abs(r["flops_per_device"] - want) / want < 0.05, r


def test_collectives_counted_inside_loops():
    """Collective in a scan body must be multiplied by trips (subprocess
    with 4 host devices for a real SPMD partition)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        import sys
        sys.path.insert(0, "src")
        from repro.launch import hlo_analysis
        mesh = jax.make_mesh((4,), ("model",))
        L, m, k = 5, 8, 64
        def f(w, x):
            def body(c, w_l):
                return jnp.tanh(c @ w_l), None
            y, _ = jax.lax.scan(body, x, w)
            return y
        ws = NamedSharding(mesh, P(None, "model", None))
        xs = NamedSharding(mesh, P(None, None))
        with mesh:
            c = jax.jit(f, in_shardings=(ws, xs)).lower(
                jax.ShapeDtypeStruct((L, k, k), jnp.float32),
                jax.ShapeDtypeStruct((m, k), jnp.float32)).compile()
        r = hlo_analysis.analyze(c.as_text())
        counts = r["collective_counts"]
        total = sum(counts.values())
        assert total >= L, (counts, total)   # one all-reduce per layer trip
        print("OK", counts)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
