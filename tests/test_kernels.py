"""Pallas kernel sweeps (interpret mode) vs the pure-jnp/numpy oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.ec import bitplane, gf256
from repro.kernels import ops, ref
from repro.kernels.gf256_matmul import gf256_matmul_planes
from repro.kernels.xor_reduce import xor_reduce_words


@pytest.mark.parametrize("m,k", [(1, 2), (2, 3), (3, 4), (2, 6), (4, 8), (1, 16)])
@pytest.mark.parametrize("nbytes", [32, 100, 1024, 4096])
def test_gf256_matmul_sweep(m, k, nbytes, rng):
    coeff = rng.integers(0, 256, size=(m, k), dtype=np.uint8)
    data = rng.integers(0, 256, size=(k, nbytes), dtype=np.uint8)
    want = gf256.gf_matmul_np(coeff, data)
    got = np.asarray(ops.gf256_matmul(coeff, jnp.asarray(data)))
    assert np.array_equal(got, want)
    # independent byte-domain oracle agrees too
    got_ref = np.asarray(ref.gf256_matmul_bytes_ref(coeff, jnp.asarray(data)))
    assert np.array_equal(got_ref, want)


@pytest.mark.parametrize("block_w", [128, 512, 1024])
def test_gf256_matmul_block_widths(block_w, rng):
    coeff = rng.integers(0, 256, size=(2, 3), dtype=np.uint8)
    data = rng.integers(0, 256, size=(3, 3000), dtype=np.uint8)
    masks = jnp.asarray(bitplane.coeff_to_masks_np(coeff))
    planes = bitplane.pack_jnp(jnp.asarray(data))
    out = gf256_matmul_planes(masks, planes, block_w=block_w, interpret=True)
    want = ref.gf256_matmul_planes_ref(masks, planes)
    assert np.array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("k", [2, 3, 5, 9])
@pytest.mark.parametrize("nbytes", [4, 64, 999, 2048])
def test_xor_reduce_sweep(k, nbytes, rng):
    x = rng.integers(0, 256, size=(k, nbytes), dtype=np.uint8)
    want = x[0].copy()
    for i in range(1, k):
        want ^= x[i]
    got = np.asarray(ops.xor_reduce(jnp.asarray(x)))
    assert np.array_equal(got, want)


def test_xor_reduce_words_direct(rng):
    w = rng.integers(0, 2**32, size=(4, 700), dtype=np.uint32)
    got = np.asarray(xor_reduce_words(jnp.asarray(w), interpret=True))
    want = w[0] ^ w[1] ^ w[2] ^ w[3]
    assert np.array_equal(got, want)


@given(st.integers(1, 512), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_bitplane_roundtrip(nbytes, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(2, nbytes), dtype=np.uint8)
    planes = bitplane.pack_np(data)
    assert np.array_equal(bitplane.unpack_np(planes, nbytes), data)
    planes_j = bitplane.pack_jnp(jnp.asarray(data))
    assert np.array_equal(np.asarray(planes_j), planes)
    back = bitplane.unpack_jnp(planes_j, nbytes)
    assert np.array_equal(np.asarray(back), data)


def test_rs_encode_reconstruct_via_kernels(rng):
    from repro.ec.rs import RSCode
    for (n, k) in [(4, 2), (6, 3), (7, 4), (6, 4)]:
        code = RSCode(n, k)
        data = rng.integers(0, 256, size=(k, 512), dtype=np.uint8)
        parity = np.asarray(
            ops.rs_encode(code.parity_coeffs(), jnp.asarray(data)))
        cw = np.concatenate([data, parity])
        failed = list(rng.choice(n, size=n - k, replace=False))
        helpers = [i for i in range(n) if i not in failed][:k]
        rec = np.asarray(ops.rs_reconstruct(
            code.repair_coeffs(tuple(failed), tuple(helpers)),
            jnp.asarray(cw[helpers])))
        assert np.array_equal(rec, cw[failed])
